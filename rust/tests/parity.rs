//! Backend parity suite (no artifacts needed): every backend the engine
//! registry can construct locally is run on the same seeded networks and
//! frames through the uniform `Backend` trait, and must produce
//! **identical** `pred` / `logits` — the simulator, the dense reference
//! and all three baseline cycle models compute the same network; they
//! differ only in cycle accounting. The PJRT backend is exercised when
//! compiled in and artifacts exist, and must report a typed
//! `Unavailable` error otherwise.

use sacsnn::engine::{Backend, BackendKind, EngineBuilder, EngineError, Frame};
use sacsnn::snn::network::testutil::random_network;
use sacsnn::util::prng::Pcg;
use std::sync::Arc;

/// The kinds that build without artifacts or optional features.
const LOCAL_KINDS: [BackendKind; 5] = [
    BackendKind::Sim,
    BackendKind::DenseRef,
    BackendKind::DenseMac,
    BackendKind::Systolic,
    BackendKind::AerArray,
];

fn frames_for(net: &sacsnn::snn::network::Network, seeds: &[u64]) -> Vec<Frame> {
    let (h, w, c) = net.input_shape();
    seeds
        .iter()
        .map(|&seed| {
            let mut rng = Pcg::new(seed);
            let data = (0..h * w * c).map(|_| rng.below(256) as u8).collect();
            Frame::from_u8(h, w, c, data).unwrap()
        })
        .collect()
}

#[test]
fn every_backend_agrees_on_pred_and_logits() {
    for net_seed in [101u64, 202, 303] {
        let net = Arc::new(random_network(net_seed));
        let builder = EngineBuilder::new(Arc::clone(&net)).lanes(4);
        let mut backends: Vec<Box<dyn Backend>> = LOCAL_KINDS
            .iter()
            .map(|&k| builder.build(k).unwrap())
            .collect();
        for frame in frames_for(&net, &[1, 2, 3]) {
            let reference = backends[0].infer(&frame).unwrap();
            assert_eq!(reference.logits.len(), net.n_classes);
            for backend in backends.iter_mut().skip(1) {
                let got = backend.infer(&frame).unwrap();
                assert_eq!(
                    got.logits,
                    reference.logits,
                    "net {net_seed}: {} disagrees with {}",
                    backend.name(),
                    BackendKind::Sim.name(),
                );
                assert_eq!(got.pred, reference.pred, "net {net_seed}: {}", backend.name());
            }
        }
    }
}

#[test]
fn spike_counts_agree_where_reported() {
    // sim and dense-ref both report full per-(t, layer) spike counts;
    // they must match exactly (the golden cross-check signal).
    let net = Arc::new(random_network(404));
    let builder = EngineBuilder::new(Arc::clone(&net));
    let mut sim = builder.build(BackendKind::Sim).unwrap();
    let mut dref = builder.build(BackendKind::DenseRef).unwrap();
    for frame in frames_for(&net, &[7, 8]) {
        let a = sim.infer(&frame).unwrap();
        let b = dref.infer(&frame).unwrap();
        assert_eq!(a.stats.spike_counts, b.stats.spike_counts);
        assert_eq!(a.stats.spike_counts.len(), net.t_steps);
        assert_eq!(a.stats.spike_counts[0].len(), net.conv.len());
    }
}

#[test]
fn cycle_models_differentiate_architectures() {
    // Parity is functional only — the cycle models must DISAGREE in the
    // way the paper argues: the event-driven design beats the
    // sparsity-blind baselines in PE-cycles per frame.
    let net = Arc::new(random_network(505));
    let builder = EngineBuilder::new(Arc::clone(&net));
    let frame = &frames_for(&net, &[9])[0];
    let mut sim = builder.build(BackendKind::Sim).unwrap();
    let ours = sim.infer(frame).unwrap();
    let our_pe_cycles = ours.stats.total_cycles as f64 * sim.cycle_model().n_pes as f64;
    for kind in [BackendKind::DenseMac, BackendKind::Systolic, BackendKind::AerArray] {
        let mut b = builder.build(kind).unwrap();
        let theirs = b.infer(frame).unwrap();
        assert!(theirs.stats.total_cycles > 0, "{kind}");
        let their_pe_cycles =
            theirs.stats.total_cycles as f64 * b.cycle_model().n_pes as f64;
        assert!(
            their_pe_cycles > our_pe_cycles,
            "{kind}: {their_pe_cycles} !> {our_pe_cycles}"
        );
    }
}

#[test]
fn lanes_are_functionally_invariant_through_the_trait() {
    let net = Arc::new(random_network(606));
    let frame = &frames_for(&net, &[11])[0];
    let mut x1 = EngineBuilder::new(Arc::clone(&net)).lanes(1).build(BackendKind::Sim).unwrap();
    let mut x8 = EngineBuilder::new(Arc::clone(&net)).lanes(8).build(BackendKind::Sim).unwrap();
    let a = x1.infer(frame).unwrap();
    let b = x8.infer(frame).unwrap();
    assert_eq!(a.logits, b.logits);
    assert!(b.stats.total_cycles < a.stats.total_cycles, "×8 must be faster");
}

#[test]
fn infer_batch_is_bit_identical_to_sequential_infer() {
    // The batched-inference contract: for EVERY registered local backend,
    // `infer_batch` must equal a sequential `infer` loop bit for bit —
    // preds, logits AND the full stats block — for batch sizes {0, 1, 7,
    // 64} and (for the sharded sim executor) thread counts {1, 4}.
    let net = Arc::new(random_network(909));
    let builder = EngineBuilder::new(Arc::clone(&net)).lanes(4);
    for batch_len in [0usize, 1, 7, 64] {
        let seeds: Vec<u64> = (0..batch_len as u64).map(|i| 1000 + i).collect();
        let frames = frames_for(&net, &seeds);
        for &kind in &LOCAL_KINDS {
            // sequential reference on a fresh backend
            let mut seq = builder.build(kind).unwrap();
            let want: Vec<_> = frames.iter().map(|f| seq.infer(f).unwrap()).collect();
            for threads in [1usize, 4] {
                // threads is a sim-only knob; other kinds ignore it and
                // exercise the default infer_batch loop — both paths must
                // hold the same contract.
                let mut batched = builder.clone().threads(threads).build(kind).unwrap();
                let mut out = Vec::new();
                batched.infer_batch(&frames, &mut out).unwrap();
                assert_eq!(out.len(), batch_len, "{kind} t={threads} n={batch_len}");
                for (i, (got, want)) in out.iter().zip(&want).enumerate() {
                    let ctx = format!("{kind} threads={threads} n={batch_len} frame={i}");
                    assert_eq!(got.pred, want.pred, "{ctx}");
                    assert_eq!(got.logits, want.logits, "{ctx}");
                    assert_eq!(got.stats, want.stats, "{ctx}");
                }
                // the output vec must be reusable verbatim (recycled
                // buffers can't leak previous results)
                batched.infer_batch(&frames, &mut out).unwrap();
                for (i, (got, want)) in out.iter().zip(&want).enumerate() {
                    assert_eq!(
                        got.logits, want.logits,
                        "{kind} threads={threads} n={batch_len} frame={i} (recycled)"
                    );
                }
            }
        }
    }
}

#[test]
fn pipelined_stream_is_bit_identical_to_sequential_infer() {
    // The self-timed layer pipeline contract: for batch sizes {0, 1, 7,
    // 64} × pipeline depths {1, 2, full}, BOTH streaming entry points —
    // `infer_stream` (iterator → sink, results in input order) and
    // `infer_batch` (the coordinator's dispatch, which the pipelined sim
    // routes through its stream path) — must equal sequential `infer`
    // bit for bit: preds, logits AND the full stats block. The recycled
    // output vec must also stay exact across dispatches.
    let net = Arc::new(random_network(1212));
    let builder = EngineBuilder::new(Arc::clone(&net)).lanes(4);
    for batch_len in [0usize, 1, 7, 64] {
        let seeds: Vec<u64> = (0..batch_len as u64).map(|i| 2000 + i).collect();
        let frames = frames_for(&net, &seeds);
        let mut seq = builder.build(BackendKind::Sim).unwrap();
        let want: Vec<_> = frames.iter().map(|f| seq.infer(f).unwrap()).collect();
        for depth in [1usize, 2, usize::MAX] {
            let dname = if depth == usize::MAX { "full".to_string() } else { depth.to_string() };
            let mut pipe = builder.clone().pipeline(depth).build(BackendKind::Sim).unwrap();

            // streaming path: sink observes results in input order (and
            // hands back a container for the engine to recycle)
            let mut streamed = Vec::new();
            pipe.infer_stream(&mut frames.iter().cloned(), &mut |_, inf| {
                streamed.push(inf);
                sacsnn::engine::Inference::default()
            })
            .unwrap();
            assert_eq!(streamed.len(), batch_len, "depth={dname} n={batch_len}");
            for (i, (got, want)) in streamed.iter().zip(&want).enumerate() {
                let ctx = format!("stream depth={dname} n={batch_len} frame={i}");
                assert_eq!(got.pred, want.pred, "{ctx}");
                assert_eq!(got.logits, want.logits, "{ctx}");
                assert_eq!(got.stats, want.stats, "{ctx}");
            }

            // batch path, twice (recycled containers must not leak state)
            let mut out = Vec::new();
            for round in 0..2 {
                pipe.infer_batch(&frames, &mut out).unwrap();
                assert_eq!(out.len(), batch_len, "depth={dname} n={batch_len}");
                for (i, (got, want)) in out.iter().zip(&want).enumerate() {
                    let ctx =
                        format!("batch depth={dname} n={batch_len} frame={i} round={round}");
                    assert_eq!(got.pred, want.pred, "{ctx}");
                    assert_eq!(got.logits, want.logits, "{ctx}");
                    assert_eq!(got.stats, want.stats, "{ctx}");
                }
            }
        }
        // the threads × pipeline composition (replicated-pipeline pool)
        let mut pool = builder
            .clone()
            .pipeline(usize::MAX)
            .threads(3)
            .build(BackendKind::Sim)
            .unwrap();
        let mut out = Vec::new();
        pool.infer_batch(&frames, &mut out).unwrap();
        assert_eq!(out.len(), batch_len, "pool n={batch_len}");
        for (i, (got, want)) in out.iter().zip(&want).enumerate() {
            let ctx = format!("pool n={batch_len} frame={i}");
            assert_eq!(got.pred, want.pred, "{ctx}");
            assert_eq!(got.logits, want.logits, "{ctx}");
            assert_eq!(got.stats, want.stats, "{ctx}");
        }
    }
}

#[test]
fn session_stream_is_bit_identical_to_sequential_infer() {
    // The multi-tenant serving contract: a Session stream over N frames
    // must deliver — in feed order — results bit-identical to a
    // sequential `infer` loop on a fresh backend, for EVERY local
    // backend kind × pipeline depth {off, 2} (pipeline is a sim-only
    // knob; other kinds ignore it and must hold the same contract).
    use sacsnn::coordinator::{Server, ServerConfig, TenantConfig};

    let net = Arc::new(random_network(1414));
    let frames = frames_for(&net, &(0..10u64).map(|i| 3000 + i).collect::<Vec<_>>());
    for &kind in &LOCAL_KINDS {
        let mut seq = EngineBuilder::new(Arc::clone(&net))
            .lanes(4)
            .build(kind)
            .unwrap();
        let want: Vec<_> = frames.iter().map(|f| seq.infer(f).unwrap()).collect();
        // (pipeline, threads): plain, self-timed-pipelined, and sharded
        // tenant backends — all through the same session surface.
        for (pipeline, threads) in [(0usize, 1usize), (2, 1), (0, 3)] {
            let server = Server::start(ServerConfig {
                workers: 2,
                batch_size: 4,
                ..Default::default()
            })
            .unwrap();
            let tenant = server
                .register_tenant(
                    Arc::clone(&net),
                    TenantConfig {
                        max_inflight: 64,
                        backend: kind,
                        lanes: 4,
                        threads,
                        pipeline,
                        ..Default::default()
                    },
                )
                .unwrap();
            let mut session = server.open_session(tenant).unwrap();
            for f in &frames {
                session.feed(f).unwrap();
            }
            for (i, want) in want.iter().enumerate() {
                let ctx = format!("{kind} pipeline={pipeline} threads={threads} frame={i}");
                let got = session.recv().expect("outstanding result").unwrap();
                assert_eq!(got.id, i as u64, "feed order: {ctx}");
                assert_eq!(got.pred, want.pred, "{ctx}");
                assert_eq!(got.logits, want.logits, "{ctx}");
                assert_eq!(got.sim_cycles, want.stats.total_cycles, "{ctx}");
            }
            assert!(
                session.recv().is_none(),
                "{kind} pipeline={pipeline} threads={threads}: drained"
            );
            server.shutdown();
        }
    }
}

#[test]
fn cifar_scale_net_agrees_across_backends_and_serving() {
    // The generalized-datapath parity anchor: a CIFAR-scale topology —
    // 6 convs with mixed kernel sizes {5, 3, 1}, zero padding, a
    // stride-2 conv, and both pooling kinds — must come out
    // bit-identical through every local backend, every sim execution
    // mode (plain / pipelined / sharded / replicated-pipeline pool),
    // and a served Session.
    use sacsnn::coordinator::{Server, ServerConfig, TenantConfig};
    use sacsnn::snn::network::testutil::cifar_network;
    use sacsnn::snn::network::PoolMode;

    let net = Arc::new(cifar_network(31));
    assert!(net.conv.len() >= 6, "CIFAR-scale depth");
    assert_eq!(net.max_k(), 5, "mixed kernel sizes");
    assert!(net.conv.iter().any(|l| l.k == 1), "1x1 conv present");
    assert!(net.conv.iter().any(|l| l.stride > 1), "strided conv present");
    assert!(net.conv.iter().any(|l| l.padding > 0), "padded conv present");
    assert!(
        net.conv
            .iter()
            .any(|l| matches!(l.pool, Some(p) if p.mode == PoolMode::WinnerTakeAll)),
        "WTA pool present"
    );
    assert!(
        net.conv
            .iter()
            .any(|l| matches!(l.pool, Some(p) if p.mode == PoolMode::Average)),
        "average pool present"
    );

    let builder = EngineBuilder::new(Arc::clone(&net)).lanes(4);
    let frames = frames_for(&net, &[21, 22, 23]);

    // the frame-based dense reference anchors functional correctness
    let mut dref = builder.build(BackendKind::DenseRef).unwrap();
    let want: Vec<_> = frames.iter().map(|f| dref.infer(f).unwrap()).collect();
    for &kind in &LOCAL_KINDS {
        let mut b = builder.build(kind).unwrap();
        for (i, f) in frames.iter().enumerate() {
            let got = b.infer(f).unwrap();
            assert_eq!(got.logits, want[i].logits, "{kind} frame={i}");
            assert_eq!(got.pred, want[i].pred, "{kind} frame={i}");
        }
    }

    // sim execution modes must also match the plain sim bit for bit,
    // including the full stats block (cycle counts and all)
    let mut plain = builder.build(BackendKind::Sim).unwrap();
    let seq: Vec<_> = frames.iter().map(|f| plain.infer(f).unwrap()).collect();
    for (s, w) in seq.iter().zip(&want) {
        assert_eq!(s.stats.spike_counts, w.stats.spike_counts, "sim vs dense-ref spikes");
    }
    for (pipeline, threads) in [(2usize, 1usize), (usize::MAX, 1), (0, 3), (usize::MAX, 2)] {
        let mut b = builder
            .clone()
            .pipeline(pipeline)
            .threads(threads)
            .build(BackendKind::Sim)
            .unwrap();
        let mut out = Vec::new();
        b.infer_batch(&frames, &mut out).unwrap();
        assert_eq!(out.len(), frames.len());
        for (i, (got, want)) in out.iter().zip(&seq).enumerate() {
            let ctx = format!("pipeline={pipeline} threads={threads} frame={i}");
            assert_eq!(got.pred, want.pred, "{ctx}");
            assert_eq!(got.logits, want.logits, "{ctx}");
            assert_eq!(got.stats, want.stats, "{ctx}");
        }
    }

    // ...and through a served Session (pipelined tenant backend)
    let server = Server::start(ServerConfig { workers: 2, batch_size: 2, ..Default::default() })
        .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig { max_inflight: 16, lanes: 4, pipeline: 2, ..Default::default() },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    for f in &frames {
        session.feed(f).unwrap();
    }
    for (i, want) in seq.iter().enumerate() {
        let got = session.recv().expect("outstanding result").unwrap();
        assert_eq!(got.id, i as u64, "feed order frame={i}");
        assert_eq!(got.pred, want.pred, "served frame={i}");
        assert_eq!(got.logits, want.logits, "served frame={i}");
        assert_eq!(got.sim_cycles, want.stats.total_cycles, "served frame={i}");
    }
    assert!(session.recv().is_none(), "session drained");
    server.shutdown();
}

#[test]
fn every_backend_rejects_misshapen_frames() {
    let net = Arc::new(random_network(707));
    let builder = EngineBuilder::new(Arc::clone(&net));
    let bad = Frame::from_u8(5, 5, 1, vec![0; 25]).unwrap();
    for &kind in &LOCAL_KINDS {
        let mut b = builder.build(kind).unwrap();
        assert!(
            matches!(b.infer(&bad), Err(EngineError::ShapeMismatch { .. })),
            "{kind} accepted a misshapen frame"
        );
    }
}

#[test]
fn pjrt_backend_reports_typed_unavailability_or_works() {
    let net = Arc::new(random_network(808));
    match EngineBuilder::new(Arc::clone(&net)).build(BackendKind::Pjrt) {
        // Feature compiled in AND artifacts present: must agree with sim.
        Ok(mut pjrt) => {
            let frame = &frames_for(&net, &[13])[0];
            // A random network has no HLO artifact; reaching here means a
            // real artifact model was loaded — only check it runs.
            let _ = pjrt.infer(frame);
        }
        // Feature off, or artifacts missing: typed, actionable errors.
        Err(EngineError::Unavailable(why)) => {
            assert!(why.contains("pjrt"), "{why}");
        }
        Err(EngineError::Artifacts(_)) | Err(EngineError::Io { .. }) => {}
        Err(e) => panic!("unexpected error kind: {e}"),
    }
}
