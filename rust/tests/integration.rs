//! Integration tests over the real build artifacts: network loading,
//! dataset loading, full-system accuracy, simulator-vs-dense-reference
//! and simulator-vs-JAX-golden (PJRT) equivalence, coordinator E2E.
//!
//! All tests skip gracefully when `artifacts/` has not been built yet
//! (run `make artifacts` first) so `cargo test` stays green in any order
//! and from a fresh clone.

use sacsnn::artifact::{artifacts_dir, is_complete, Meta};
use sacsnn::coordinator::{Coordinator, ServerConfig};
use sacsnn::data::Dataset;
use sacsnn::engine::{Backend, BackendKind, EngineBuilder, EngineError};
use sacsnn::report;
use sacsnn::sim::dense_ref::DenseRef;
use sacsnn::sim::{AccelConfig, Accelerator};
use std::sync::Arc;

fn ready() -> bool {
    let ok = is_complete(&artifacts_dir());
    if !ok {
        eprintln!("SKIP: artifacts/ not built");
    }
    ok
}

#[test]
fn meta_and_weights_load() {
    if !ready() {
        return;
    }
    let (net, ds, meta) = report::env("mnist", 8).unwrap();
    assert_eq!(net.conv.len(), 3);
    assert_eq!(net.t_steps, 5);
    assert_eq!(net.input_shape(), (28, 28, 1));
    assert_eq!(net.conv[0].out_shape, (26, 26, 32));
    assert_eq!(net.conv[1].queue_shape(), (8, 8, 32));
    assert!(net.conv.iter().all(|l| l.vt > 0));
    assert!(ds.n_test() >= 100);
    assert!(meta.quant("mnist", 16).is_ok());
    // 16-bit variant loads too
    let (net16, _, _) = report::env("mnist", 16).unwrap();
    assert_eq!(net16.bits, 16);
    // fashion variant
    let (netf, dsf, _) = report::env("fashion", 8).unwrap();
    assert_eq!(netf.conv.len(), 3);
    assert!(dsf.n_test() >= 100);
}

#[test]
fn missing_artifacts_is_typed_error() {
    // Loaders must report typed errors, never panic, when pointed at
    // nothing. (No env-var mutation here: tests run concurrently.)
    let err = Meta::load(std::path::Path::new("/nonexistent-sacsnn/meta.json")).unwrap_err();
    assert!(matches!(err, EngineError::Io { .. }), "{err}");
    assert!(err.to_string().contains("meta.json"), "{err}");
}

#[test]
fn accuracy_on_real_weights() {
    if !ready() {
        return;
    }
    let (net, ds, meta) = report::env("mnist", 8).unwrap();
    let mut accel = Accelerator::new(net, AccelConfig { lanes: 8, ..Default::default() });
    let n = 60;
    let correct = (0..n)
        .filter(|&i| accel.infer_image(ds.test_image(i)).pred == ds.test_y[i] as usize)
        .count();
    let acc = correct as f64 / n as f64;
    // within sampling noise of the build-time python accuracy
    let python_acc = meta.accuracy("mnist").snn_q8;
    assert!(
        acc > python_acc - 0.12,
        "sim accuracy {acc:.3} far below python {python_acc:.3}"
    );
}

#[test]
fn sim_matches_dense_reference_on_real_weights() {
    if !ready() {
        return;
    }
    let (net, ds, _) = report::env("mnist", 8).unwrap();
    let mut accel = Accelerator::new(Arc::clone(&net), AccelConfig::default());
    for i in 0..15 {
        let img = ds.test_image(i);
        let want = DenseRef::new(&net).infer(img);
        let got = accel.infer_image(img);
        assert_eq!(got.logits, want.logits, "image {i}");
        assert_eq!(got.stats.spike_counts, want.spike_counts, "image {i}");
    }
}

#[test]
fn sim_matches_jax_golden_via_pjrt() {
    if !ready() {
        return;
    }
    // spike-exact equivalence against the AOT-lowered JAX/Pallas model;
    // skips with a typed error when built without the `pjrt` feature.
    match report::golden_check(5, BackendKind::Sim) {
        Ok(out) => assert!(out.contains("5/5"), "{out}"),
        Err(EngineError::Unavailable(why)) => eprintln!("SKIP: {why}"),
        Err(e) => panic!("golden check failed: {e}"),
    }
}

#[test]
fn q16_variant_runs_and_is_consistent() {
    if !ready() {
        return;
    }
    let (net, ds, _) = report::env("mnist", 16).unwrap();
    let mut accel = Accelerator::new(Arc::clone(&net), AccelConfig::default());
    for i in 0..5 {
        let img = ds.test_image(i);
        let want = DenseRef::new(&net).infer(img);
        let got = accel.infer_image(img);
        assert_eq!(got.logits, want.logits, "image {i}");
    }
}

#[test]
fn table_iii_shape_high_sparsity_lower_utilization() {
    if !ready() {
        return;
    }
    let (net, ds, _) = report::env("mnist", 8).unwrap();
    let mut accel = Accelerator::new(net, AccelConfig::default());
    let res = accel.infer_image(ds.test_image(0));
    let l = &res.stats.layers;
    // paper Table III reports 93/98/98% on real MNIST; our synthetic set +
    // m-TTFS repeat-firing yields a denser deep-layer regime — assert the
    // architectural invariants rather than the dataset-specific values:
    // encoded input is highly sparse, and sparsity stays substantial.
    assert!(
        l[0].input_sparsity > 0.7,
        "layer 1 input sparsity {:.2} too low",
        l[0].input_sparsity
    );
    for (i, layer) in l.iter().enumerate() {
        assert!(
            layer.input_sparsity > 0.5,
            "layer {} sparsity {:.2} too low",
            i + 1,
            layer.input_sparsity
        );
    }
    // ...and PE utilization stays substantial despite the sparsity (the
    // paper's architectural point: small PE array, kept busy). Our denser
    // synthetic activations push utilization ABOVE the paper's values —
    // the qualitative claim (never collapses to the few-percent level of
    // fmap-sized arrays) is what the architecture guarantees.
    for (i, layer) in l.iter().enumerate() {
        let u = layer.pe_utilization();
        assert!(u > 0.3 && u <= 1.0, "layer {} utilization {u:.2}", i + 1);
    }
}

#[test]
fn parallelization_shape_matches_table1() {
    if !ready() {
        return;
    }
    let (net, ds, _) = report::env("mnist", 8).unwrap();
    let fps: Vec<f64> = [1usize, 2, 4, 8, 16]
        .iter()
        .map(|&lanes| report::measure(&net, &ds, lanes, 8).fps)
        .collect();
    // monotone FPS
    for w in fps.windows(2) {
        assert!(w[1] > w[0], "{fps:?}");
    }
    // near-linear to x8 (paper: 3077→21446 ≈ 7.0×); sublinear at x16
    let s8 = fps[3] / fps[0];
    let s16 = fps[4] / fps[0];
    assert!(s8 > 5.0, "x8 speedup {s8:.2}");
    assert!(s16 < 16.0, "x16 must be sublinear, got {s16:.2}");
    assert!(s16 / s8 < 2.0, "x16/x8 ratio should roll off");
}

#[test]
fn coordinator_end_to_end_on_real_network() {
    if !ready() {
        return;
    }
    let (net, ds, _) = report::env("mnist", 8).unwrap();
    let coord = Coordinator::start(
        Arc::clone(&net),
        ServerConfig { workers: 3, lanes: 8, queue_depth: 64, batch_size: 4, ..Default::default() },
    )
    .unwrap();
    let n = 24;
    let replies: Vec<_> = (0..n)
        .map(|i| coord.submit(report::frame_for(&net, &ds, i).unwrap()).unwrap())
        .collect();
    let mut direct =
        Accelerator::new(Arc::clone(&net), AccelConfig { lanes: 8, ..Default::default() });
    for (i, rx) in replies.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        let want = direct.infer_image(ds.test_image(i));
        assert_eq!(resp.pred, want.pred, "request {i}");
        assert_eq!(resp.logits, want.logits, "request {i}");
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    coord.shutdown();
}

#[test]
fn coordinator_heterogeneous_pool_on_real_network() {
    if !ready() {
        return;
    }
    // Two distinct Backend implementations behind one queue (acceptance
    // criterion: the coordinator serves ≥ 2 backend kinds).
    let (net, ds, _) = report::env("mnist", 8).unwrap();
    let builder = EngineBuilder::new(Arc::clone(&net)).lanes(8);
    let backends = vec![
        builder.build(BackendKind::Sim).unwrap(),
        builder.build(BackendKind::DenseRef).unwrap(),
    ];
    let coord = Coordinator::start_pool(
        backends,
        ServerConfig { queue_depth: 64, batch_size: 4, ..Default::default() },
    )
    .unwrap();
    let want = DenseRef::new(&net).infer(ds.test_image(0));
    let replies: Vec<_> = (0..16)
        .map(|_| coord.submit(report::frame_for(&net, &ds, 0).unwrap()).unwrap())
        .collect();
    for rx in replies {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.logits, want.logits, "served by {}", resp.backend);
        assert!(resp.backend == "sim" || resp.backend == "dense-ref");
    }
    coord.shutdown();
}

#[test]
fn baselines_functionally_agree_and_are_slower() {
    if !ready() {
        return;
    }
    let (net, ds, _) = report::env("mnist", 8).unwrap();
    let mut accel = Accelerator::new(Arc::clone(&net), AccelConfig::default());
    let img = ds.test_image(0);
    let ours = accel.infer_image(img);
    let frame = report::frame_for(&net, &ds, 0).unwrap();
    let builder = EngineBuilder::new(Arc::clone(&net));
    for kind in [BackendKind::Systolic, BackendKind::AerArray, BackendKind::DenseMac] {
        let mut backend = builder.build(kind).unwrap();
        let result = backend.infer(&frame).unwrap();
        assert_eq!(result.logits, ours.logits, "{kind} functional mismatch");
        // per-PE efficiency: ours uses 9 PEs at high utilization; the
        // sparsity-blind baselines burn far more PE-cycles per frame
        let their_pe_cycles =
            result.stats.total_cycles as f64 * backend.cycle_model().n_pes as f64;
        let our_pe_cycles = ours.stats.total_cycles as f64 * 9.0;
        assert!(
            their_pe_cycles > our_pe_cycles,
            "{kind}: {their_pe_cycles} !> {our_pe_cycles}"
        );
    }
}

#[test]
fn dataset_sanity() {
    if !ready() {
        return;
    }
    let ds = Dataset::load(&artifacts_dir(), "mnist").unwrap();
    assert_eq!(ds.h, 28);
    assert_eq!(ds.w, 28);
    // class balance within reason
    let mut counts = [0usize; 10];
    for &y in &ds.test_y {
        counts[y as usize] += 1;
    }
    assert!(counts.iter().all(|&c| c > ds.n_test() / 40));
}

#[test]
fn meta_quant_consistent_with_loaded_network() {
    if !ready() {
        return;
    }
    let meta = Meta::load(&artifacts_dir().join("meta.json")).unwrap();
    let q = meta.quant("mnist", 8).unwrap();
    let (net, _, _) = report::env("mnist", 8).unwrap();
    for (i, layer) in net.conv.iter().enumerate() {
        assert_eq!(layer.vt, q.vt_q[i], "layer {i} vt");
        let wmax = layer.w.iter().map(|w| w.abs()).max().unwrap();
        assert!(wmax <= 127, "q8 weights must fit 8 bits, got {wmax}");
    }
    assert_eq!(net.sat.max, q.sat_max);
}
