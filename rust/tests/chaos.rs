//! Chaos soak: the self-healing serving contract under deterministic
//! fault injection ([`sacsnn::faults`]).
//!
//! The invariants refereed here, end to end through the public API:
//!
//! 1. **Exactly once** — every fed frame is answered exactly once, with
//!    a result or a typed error, whatever the backends do.
//! 2. **Results intact** — frames that survive retries are bit-identical
//!    to a fault-free run (healing never corrupts an answer).
//! 3. **Bounded stalls** — a wedged dispatch is reaped close to its
//!    tenant's `dispatch_timeout`, never after the hang resolves.
//! 4. **The pool never shrinks** — after any heal or replacement the
//!    server reports its configured worker count.

use sacsnn::coordinator::{Server, ServerConfig, TenantConfig};
use sacsnn::engine::EngineError;
use sacsnn::faults::FaultPlan;
use sacsnn::sim::{AccelConfig, Accelerator};
use sacsnn::snn::network::testutil::random_network;
use sacsnn::traffic::{generate, replay_tolerant, TraceSpec};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[test]
fn chaos_soak_answers_every_frame_exactly_once_with_intact_results() {
    let spec = TraceSpec { tenants: 1, frames_per_tenant: 60, ..Default::default() };
    let trace = generate(&spec);
    let net = Arc::new(random_network(42));

    // Golden run: the same frames through a direct accelerator — the
    // bit-exact reference every successful chaos reply must match.
    let mut direct =
        Accelerator::new(Arc::clone(&net), AccelConfig { lanes: 2, ..Default::default() });
    let golden: Vec<Vec<i64>> =
        trace.iter().map(|ev| direct.infer_image(ev.frame.as_u8().unwrap()).logits).collect();

    // Chaos run: panics, stalls past the dispatch deadline, and
    // truncated streams, all seeded and budget-bounded.
    let plan = Arc::new(
        FaultPlan::new(1234)
            .panics(0.15)
            .stalls(0.05, 80)
            .truncations(0.10)
            .max_faults(10),
    );
    let server = Server::start(ServerConfig {
        workers: 2,
        batch_size: 4,
        restart_backoff_ms: 0,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig {
                max_inflight: 8,
                lanes: 2,
                dispatch_timeout: Duration::from_millis(40),
                max_retries: 3,
                fault_plan: Some(Arc::clone(&plan)),
                ..Default::default()
            },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    let mut replies = Vec::with_capacity(trace.len());
    for ev in &trace {
        session.feed_yielding(&ev.frame, &mut |reply| replies.push(reply)).unwrap();
    }
    replies.extend(session.finish());

    // (1) exactly once: one reply per fed frame, in feed order
    assert_eq!(replies.len(), trace.len(), "every fed frame answered exactly once");
    let mut ok = 0usize;
    let mut failed = 0usize;
    for (i, reply) in replies.iter().enumerate() {
        match reply {
            // (2) survivors are bit-identical to the fault-free run
            Ok(resp) => {
                assert_eq!(resp.logits, golden[i], "frame {i} result corrupted by healing");
                ok += 1;
            }
            Err(
                EngineError::WorkerPanicked { .. }
                | EngineError::DeadlineExceeded { .. }
                | EngineError::PoisonFrame { .. }
                | EngineError::Backend(_)
                | EngineError::Msg(_),
            ) => failed += 1,
            Err(e) => panic!("frame {i}: unexpected error kind {e}"),
        }
    }
    assert_eq!(ok + failed, trace.len());
    assert!(plan.counts().total() >= 1, "the seeded plan must actually inject");
    let snap = server.snapshot();
    assert!(
        snap.service.worker_restarts >= 1,
        "panics/stalls at these rates must trigger at least one heal: {:?}",
        plan.counts()
    );
    // (4) the pool healed back to its configured size
    assert_eq!(server.live_workers(), 2, "the pool must never shrink");
    // retried frames are visible in the per-tenant counters
    let row = server.tenant_state(tenant).unwrap();
    assert_eq!(row.completed, ok as u64);
    assert_eq!(row.failed, failed as u64);
    server.shutdown();
}

#[test]
fn deadline_reap_is_bounded_and_pool_recovers() {
    // One certain 500 ms stall against a 40 ms dispatch deadline: the
    // watchdog must fail the frame typed well before the stall resolves
    // (bounded by deadline + watchdog period, asserted with slack), and
    // a replacement worker must serve the next frame.
    let net = Arc::new(random_network(51));
    let plan = Arc::new(FaultPlan::new(7).stalls(1.0, 500).max_faults(1));
    let server = Server::start(ServerConfig {
        workers: 1,
        batch_size: 1,
        restart_backoff_ms: 0,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig {
                max_inflight: 4,
                lanes: 2,
                dispatch_timeout: Duration::from_millis(40),
                fault_plan: Some(plan),
                ..Default::default()
            },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    let f = sacsnn::engine::Frame::from_u8(28, 28, 1, vec![64; 784]).unwrap();
    let t0 = Instant::now();
    session.feed(&f).unwrap();
    let err = session.recv().expect("one frame outstanding").unwrap_err();
    let elapsed = t0.elapsed();
    assert!(
        matches!(err, EngineError::DeadlineExceeded { timeout_ms: 40, .. }),
        "{err}"
    );
    assert!(
        elapsed < Duration::from_millis(400),
        "reap must not wait out the 500 ms hang (took {elapsed:?})"
    );
    // the stall budget is spent: the replacement serves normally
    session.feed(&f).unwrap();
    let resp = session.recv().expect("outstanding").unwrap();
    assert!(resp.pred < 10);
    assert_eq!(server.live_workers(), 1, "replacement restored the pool");
    assert!(server.snapshot().service.worker_restarts >= 1);
    server.shutdown();
}

#[test]
fn poison_frame_quarantined_after_retry_budget() {
    // A frame that fails EVERY dispatch must not retry forever: after
    // max_retries attempts it is quarantined with a typed PoisonFrame.
    let net = Arc::new(random_network(52));
    let plan = Arc::new(FaultPlan::new(9).panics(1.0));
    let server = Server::start(ServerConfig {
        workers: 1,
        batch_size: 1,
        restart_backoff_ms: 0,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig {
                max_inflight: 4,
                lanes: 2,
                max_retries: 2,
                fault_plan: Some(plan),
                ..Default::default()
            },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    let f = sacsnn::engine::Frame::from_u8(28, 28, 1, vec![64; 784]).unwrap();
    session.feed(&f).unwrap();
    let err = session.recv().expect("answered, not retried forever").unwrap_err();
    assert!(matches!(err, EngineError::PoisonFrame { retries: 2, .. }), "{err}");
    let row = server.tenant_state(tenant).unwrap();
    assert_eq!(row.retries, 2, "both retry attempts counted");
    assert_eq!(row.quarantined, 1);
    assert_eq!(server.snapshot().service.worker_restarts, 3, "one heal per panic");
    assert_eq!(server.live_workers(), 1);
    server.shutdown();
}

#[test]
fn restart_cap_limps_typed_instead_of_crash_looping() {
    // Past max_worker_restarts consecutive heals the lineage stops
    // crash-looping: dispatches are answered with the standing fault,
    // typed, and the heal counter stops climbing.
    let net = Arc::new(random_network(53));
    let plan = Arc::new(FaultPlan::new(11).panics(1.0));
    let server = Server::start(ServerConfig {
        workers: 1,
        batch_size: 1,
        max_worker_restarts: 2,
        restart_backoff_ms: 0,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig {
                max_inflight: 8,
                lanes: 2,
                fault_plan: Some(plan),
                ..Default::default()
            },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    let f = sacsnn::engine::Frame::from_u8(28, 28, 1, vec![64; 784]).unwrap();
    for _ in 0..6 {
        session.feed(&f).unwrap();
    }
    server.drain();
    let replies = session.finish();
    assert_eq!(replies.len(), 6, "limping must still answer everything");
    for reply in &replies {
        let err = reply.as_ref().unwrap_err();
        assert!(matches!(err, EngineError::WorkerPanicked { .. }), "{err}");
    }
}

#[test]
fn shutdown_mid_respawn_completes_with_no_orphans() {
    // Regression: shutting down while the watchdog is replacing a reaped
    // worker must join cleanly — the replacement observes the shutdown
    // mode on its first injector visit instead of parking forever, and
    // the wedged original is detached, not waited out.
    let net = Arc::new(random_network(54));
    let plan = Arc::new(FaultPlan::new(13).stalls(1.0, 300).max_faults(1));
    let server = Server::start(ServerConfig {
        workers: 1,
        batch_size: 1,
        restart_backoff_ms: 0,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig {
                max_inflight: 4,
                lanes: 2,
                dispatch_timeout: Duration::from_millis(30),
                max_retries: 1,
                fault_plan: Some(plan),
                ..Default::default()
            },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    let f = sacsnn::engine::Frame::from_u8(28, 28, 1, vec![64; 784]).unwrap();
    session.feed(&f).unwrap();
    // let the watchdog reap the stalled dispatch and spawn a replacement
    std::thread::sleep(Duration::from_millis(80));
    let t0 = Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_millis(250),
        "shutdown must detach the wedged thread, not wait out its 300 ms hang"
    );
    let replies = session.finish();
    assert_eq!(replies.len(), 1, "the frame is answered exactly once");
}

#[test]
fn recv_deadline_times_out_typed_without_consuming() {
    let net = Arc::new(random_network(55));
    let plan = Arc::new(FaultPlan::new(17).stalls(1.0, 150).max_faults(1));
    let server = Server::start(ServerConfig {
        workers: 1,
        batch_size: 1,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig {
                max_inflight: 4,
                lanes: 2,
                fault_plan: Some(plan), // stall only; no server-side deadline
                ..Default::default()
            },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    // nothing outstanding → Ok(None), not a timeout
    assert!(session.recv_deadline(Duration::from_millis(1)).unwrap().is_none());
    let f = sacsnn::engine::Frame::from_u8(28, 28, 1, vec![64; 784]).unwrap();
    session.feed(&f).unwrap();
    let err = session.recv_deadline(Duration::from_millis(10)).unwrap_err();
    assert!(matches!(err, EngineError::DeadlineExceeded { timeout_ms: 10, .. }), "{err}");
    // the timed-out wait consumed nothing: the result still arrives
    let resp = session.recv().expect("still outstanding").unwrap();
    assert!(resp.pred < 10);
    server.shutdown();
}

#[test]
fn tolerant_replay_reports_availability_under_chaos() {
    let spec = TraceSpec { tenants: 1, frames_per_tenant: 30, ..Default::default() };
    let trace = generate(&spec);
    let net = Arc::new(random_network(56));
    let plan = Arc::new(FaultPlan::new(21).panics(0.3).max_faults(5));
    let server = Server::start(ServerConfig {
        workers: 1,
        batch_size: 4,
        restart_backoff_ms: 0,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            TenantConfig {
                max_inflight: 8,
                lanes: 2,
                fault_plan: Some(plan),
                ..Default::default()
            },
        )
        .unwrap();
    let mut sessions = vec![server.open_session(tenant).unwrap()];
    let chaos = replay_tolerant(&mut sessions, &trace, 0.0).unwrap();
    assert_eq!(chaos.ok + chaos.failed, 30, "every frame counted exactly once");
    assert!(chaos.failed >= 1, "certain-panic budget must cost some frames");
    assert_eq!(chaos.report.frames(), chaos.ok, "latency recorded for successes only");
    let availability = chaos.availability();
    assert!(availability < 1.0 && availability > 0.0, "availability {availability}");
    server.shutdown();
}
