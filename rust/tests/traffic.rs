//! Traffic subsystem suite: the cost-aware dispatch equivalence contract
//! and the property tests behind the trace-replay tail-latency harness.
//!
//! The load-bearing claim of cost-aware ingress is that budget-packed
//! dispatch changes WHEN work is grouped, never WHAT is computed: for the
//! same trace, a cost-aware server and a frame-count server must deliver
//! bit-identical per-tenant response sequences, both equal to a
//! sequential `infer` loop on a fresh backend. The same contract covers
//! the cost-weighted WRR: with heterogeneous per-tenant networks the
//! scheduler reweights visits by modeled nominal cycles, and results must
//! still be bit-identical. The property tests pin the pieces the
//! harness's numbers rest on: histogram quantiles bounded by min/max and
//! monotone in rank, cost estimates monotone in event count, and trace
//! generation deterministic per seed.

use sacsnn::coordinator::{Server, ServerConfig, TenantConfig};
use sacsnn::engine::{Backend, BackendKind, EngineBuilder};
use sacsnn::snn::network::testutil::random_network;
use sacsnn::snn::network::{spec, Network};
use sacsnn::traffic::{generate, CostModel, LatencyHistogram, TraceEvent, TraceSpec};
use sacsnn::util::prop;
use std::sync::Arc;

/// `(pred, logits, sim_cycles)` of one served frame.
type Served = (usize, Vec<i64>, u64);

/// Serve `trace` through a server with the given `cost_aware` setting —
/// one tenant per entry of `nets` — and return, per tenant, the
/// `(pred, logits, sim_cycles)` sequence in feed order. Cross-tenant
/// interleave is scheduling-dependent by design, so the per-tenant
/// sequence is the bit-identity observable.
fn serve_trace(nets: &[Arc<Network>], trace: &[TraceEvent], cost_aware: bool) -> Vec<Vec<Served>> {
    let tenants = nets.len();
    let server = Server::start(ServerConfig {
        workers: 2,
        batch_size: 4,
        cost_aware,
        ..Default::default()
    })
    .unwrap();
    let mut sessions = Vec::with_capacity(tenants);
    for net in nets {
        let tenant = server
            .register_tenant(
                Arc::clone(net),
                TenantConfig { max_inflight: 256, lanes: 2, ..Default::default() },
            )
            .unwrap();
        sessions.push(server.open_session(tenant).unwrap());
    }
    for ev in trace {
        sessions[ev.tenant].feed(&ev.frame).unwrap();
    }
    let mut out: Vec<Vec<Served>> = Vec::with_capacity(tenants);
    for session in &mut sessions {
        let mut replies = Vec::new();
        while let Some(reply) = session.recv() {
            let r = reply.unwrap();
            assert_eq!(r.id, replies.len() as u64, "feed order within a tenant");
            replies.push((r.pred, r.logits, r.sim_cycles));
        }
        out.push(replies);
    }
    server.shutdown();
    out
}

#[test]
fn cost_packed_dispatch_is_bit_identical_to_frame_count_dispatch() {
    let net = Arc::new(random_network(2024));
    let spec = TraceSpec {
        tenants: 3,
        frames_per_tenant: 20,
        shape: net.input_shape(),
        ..Default::default()
    };
    let trace = generate(&spec);

    let nets = vec![Arc::clone(&net); spec.tenants];
    let packed = serve_trace(&nets, &trace, true);
    let counted = serve_trace(&nets, &trace, false);
    assert_eq!(packed, counted, "cost-aware packing changed results");

    // ...and both match a sequential infer loop on a fresh backend.
    let mut seq = EngineBuilder::new(Arc::clone(&net)).lanes(2).build(BackendKind::Sim).unwrap();
    for tenant in 0..spec.tenants {
        let frames: Vec<_> = trace.iter().filter(|e| e.tenant == tenant).collect();
        assert_eq!(packed[tenant].len(), frames.len(), "tenant {tenant}: every frame served");
        for (i, ev) in frames.iter().enumerate() {
            let want = seq.infer(&ev.frame).unwrap();
            let (pred, logits, cycles) = &packed[tenant][i];
            assert_eq!(*pred, want.pred, "tenant {tenant} frame {i}");
            assert_eq!(*logits, want.logits, "tenant {tenant} frame {i}");
            assert_eq!(*cycles, want.stats.total_cycles, "tenant {tenant} frame {i}");
        }
    }
}

#[test]
fn cost_weighted_wrr_with_heterogeneous_nets_is_bit_identical() {
    // Two topologies with the same input shape but different modeled
    // cost: the paper net vs a deeper/wider 28×28 net. With cost_aware
    // on, equal-weight tenants get WRR visits normalized by nominal
    // cycles (the cheap net is visited more often) — and the per-tenant
    // results must STILL match both the frame-count scheduler and a
    // sequential infer loop on each tenant's own network.
    let light = Arc::new(random_network(2025));
    let heavy = Arc::new(spec::build("28x28x1-16C5p2-P2-32C3-16C3-F10", 2025).unwrap());
    assert_ne!(
        CostModel::from_network(&light).nominal_cycles(),
        CostModel::from_network(&heavy).nominal_cycles(),
        "nets must differ in modeled cost for the reweighting to engage"
    );
    let nets = vec![Arc::clone(&light), Arc::clone(&heavy), Arc::clone(&light)];
    let spec_t = TraceSpec {
        tenants: nets.len(),
        frames_per_tenant: 12,
        shape: light.input_shape(),
        ..Default::default()
    };
    let trace = generate(&spec_t);

    let weighted = serve_trace(&nets, &trace, true);
    let uniform = serve_trace(&nets, &trace, false);
    assert_eq!(weighted, uniform, "cost-weighted WRR changed results");

    for (tenant, net) in nets.iter().enumerate() {
        let mut seq = EngineBuilder::new(Arc::clone(net)).lanes(2).build(BackendKind::Sim).unwrap();
        let frames: Vec<_> = trace.iter().filter(|e| e.tenant == tenant).collect();
        assert_eq!(weighted[tenant].len(), frames.len(), "tenant {tenant}: every frame served");
        for (i, ev) in frames.iter().enumerate() {
            let want = seq.infer(&ev.frame).unwrap();
            let (pred, logits, cycles) = &weighted[tenant][i];
            assert_eq!(*pred, want.pred, "tenant {tenant} frame {i}");
            assert_eq!(*logits, want.logits, "tenant {tenant} frame {i}");
            assert_eq!(*cycles, want.stats.total_cycles, "tenant {tenant} frame {i}");
        }
    }
}

#[test]
fn histogram_quantiles_are_bounded_and_monotone() {
    prop::check("histogram quantile bounds", 40, |rng| {
        let mut h = LatencyHistogram::new();
        let n = 1 + rng.below(400) as usize;
        let mut lo = u64::MAX;
        let mut hi = 0u64;
        for _ in 0..n {
            // span several power-of-two bucket groups, including exact
            // sub-32 values and multi-million-µs outliers
            let v = rng.below(1 << (1 + rng.below(22))) as u64;
            lo = lo.min(v);
            hi = hi.max(v);
            h.record(v);
        }
        let mut prev = 0u64;
        for step in 0..=20 {
            let q = step as f64 / 20.0;
            let got = h.quantile(q);
            if got < lo || got > hi {
                return Err(format!("q{q}: {got} outside [{lo}, {hi}]"));
            }
            if got < prev {
                return Err(format!("q{q}: {got} < previous quantile {prev}"));
            }
            prev = got;
        }
        // q0 clamps exactly to min; q1 lands in max's bucket, whose lower
        // bound is within one 1/32 sub-bucket of max.
        if h.quantile(0.0) != lo {
            return Err(format!("q0 {} must equal min {lo}", h.quantile(0.0)));
        }
        if h.quantile(1.0) < hi.saturating_sub(hi / 32 + 1) {
            return Err(format!("q1 {} too far below max {hi}", h.quantile(1.0)));
        }
        Ok(())
    });
}

#[test]
fn cost_estimate_is_monotone_in_event_count() {
    prop::check("cost estimate monotone", 20, |rng| {
        let model = CostModel::from_network(&random_network(rng.below(1 << 20) as u64));
        let mut prev = model.estimate(0);
        for events in [1u64, 2, 10, 100, 784, 10_000, 1 << 20] {
            let e = model.estimate(events);
            if e < prev {
                return Err(format!("estimate({events}) = {e} < {prev}"));
            }
            prev = e;
        }
        Ok(())
    });
}

#[test]
fn trace_generation_is_deterministic_per_seed() {
    prop::check("trace determinism", 10, |rng| {
        let spec = TraceSpec {
            tenants: 1 + rng.below(5) as usize,
            frames_per_tenant: 1 + rng.below(30) as usize,
            seed: rng.below(1 << 30) as u64,
            ..Default::default()
        };
        let a = generate(&spec);
        let b = generate(&spec);
        if a.len() != b.len() {
            return Err(format!("lengths differ: {} vs {}", a.len(), b.len()));
        }
        for (x, y) in a.iter().zip(&b) {
            if (x.at_us, x.tenant, x.seq) != (y.at_us, y.tenant, y.seq) || x.frame != y.frame {
                return Err(format!("event diverges: t{} seq{}", x.tenant, x.seq));
            }
        }
        // a different seed must not reproduce the same arrival process
        let other = generate(&TraceSpec { seed: spec.seed ^ 1, ..spec });
        let same = a
            .iter()
            .zip(&other)
            .all(|(x, y)| x.at_us == y.at_us && x.frame.bytes() == y.frame.bytes());
        if same {
            return Err("seed change did not change the trace".into());
        }
        Ok(())
    });
}
