//! Source-level static analysis of `rust/src` — a lint pass that runs
//! as an ordinary tier-1 test, std-only (no syn, no regex crate).
//!
//! Enforced rules (each demonstrably fails on a seeded violation — see
//! the `fixtures` module at the bottom):
//!
//! 1. **SAFETY-audited unsafe** — every `unsafe` block or `unsafe impl`
//!    must carry a `// SAFETY:` comment in its directly adjacent
//!    comment block (`unsafe fn` *declarations* are exempt: their
//!    obligation sits on callers, matching
//!    `clippy::undocumented_unsafe_blocks`).
//! 2. **Serving-path panic ban** — no `.unwrap()` / `.expect(` /
//!    `panic!(` / `unreachable!(` / `todo!(` / `unimplemented!(` in
//!    `coordinator/`, `traffic/`, or `engine/` outside `#[cfg(test)]`
//!    regions. The serving layer answers with typed [`EngineError`]s;
//!    a panic in a worker is a fault, never a control-flow tool.
//! 3. **Hot-path allocation fences** — inside a
//!    `// hot-path: alloc-free` … `// hot-path: end` region, no
//!    heap-allocating calls (`Vec::new`, `vec![`, `Box::new(`,
//!    `format!(`, `.clone()`, `.to_vec()`, `.to_string()`,
//!    `String::new`, `with_capacity(`, `.collect()`). The fences mark
//!    the regions `tests/zero_alloc.rs` proves allocation-free at
//!    runtime; this pass keeps casual edits from silently reopening
//!    them. Unbalanced fences are themselves violations.
//! 4. **Lock-order discipline** — the lock-order-audited files
//!    (`coordinator/server.rs`, `session.rs`, `tenants.rs`) must not
//!    name a raw `std::sync` `Mutex` / `RwLock` / `Condvar`; every
//!    primitive there goes through `util::dbc::Ordered*` so the
//!    debug-build shadow detector sees every acquisition.
//! 5. **Justified allows** — every `#[allow(...)]` (incl. `cfg_attr`
//!    forms) outside tests carries an adjacent `// allow:` comment
//!    saying *why* the lint is wrong here.
//! 6. **Rank table closure** — every `rank::NAME` mentioned anywhere in
//!    the tree must exist as a `pub const NAME: u16` in the
//!    `util/dbc.rs` rank table, and the declared ranks must be unique
//!    (two locks sharing a rank could deadlock without the detector
//!    firing).
//!
//! `EngineError` is [`sacsnn::engine::EngineError`].

use std::fs;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------
// Source model: per-line code (strings blanked, comments removed),
// comment text, and whether the line sits in a `#[cfg(test)]` region.
// ---------------------------------------------------------------------

struct FileModel {
    /// Raw source lines.
    raw: Vec<String>,
    /// Code with string/char literal contents blanked and `//` comments
    /// removed — token scans run against this.
    code: Vec<String>,
    /// The `//...` comment tail of each line (empty if none).
    comment: Vec<String>,
    /// Whether the line is inside a `#[cfg(test)]`-gated item.
    in_test: Vec<bool>,
}

/// Split one line into (code-with-literals-blanked, comment-text).
/// Handles string escapes and `'x'` / `'\x'` char literals; lifetimes
/// pass through untouched.
fn strip_line(line: &str) -> (String, String) {
    let chars: Vec<char> = line.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(n);
    let mut comment = String::new();
    let mut i = 0;
    while i < n {
        let ch = chars[i];
        if ch == '"' {
            out.push('"');
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    out.push_str("  ");
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                }
                out.push(' ');
                i += 1;
            }
            continue;
        }
        if ch == '\'' {
            // char literal with escape: '\x' ... find the closing quote
            if i + 2 < n && chars[i + 1] == '\\' {
                if let Some(j) = (i + 2..n).find(|&j| chars[j] == '\'') {
                    out.push('\'');
                    for _ in i + 1..j {
                        out.push(' ');
                    }
                    out.push('\'');
                    i = j + 1;
                    continue;
                }
            }
            // plain char literal 'x'
            if i + 2 < n && chars[i + 2] == '\'' {
                out.push_str("'  '");
                i += 3;
                continue;
            }
            // otherwise a lifetime tick
            out.push('\'');
            i += 1;
            continue;
        }
        if ch == '/' && i + 1 < n && chars[i + 1] == '/' {
            comment = chars[i..].iter().collect();
            break;
        }
        out.push(ch);
        i += 1;
    }
    (out, comment)
}

/// Build the [`FileModel`]: strip every line and track `#[cfg(test)]`
/// regions by brace depth (the attribute arms the tracker; the region
/// lasts until depth returns to the attribute's level).
fn analyze(src: &str) -> FileModel {
    let mut raw = Vec::new();
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut in_test = Vec::new();
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut armed_depth: i64 = 0;
    let mut test_depth: Option<i64> = None;
    for line in src.lines() {
        let (c, com) = strip_line(line);
        if c.contains("#[cfg(test)]") {
            armed = true;
            armed_depth = depth;
        }
        in_test.push(test_depth.is_some() || armed);
        depth += c.matches('{').count() as i64 - c.matches('}').count() as i64;
        if armed && depth > armed_depth {
            test_depth = Some(armed_depth);
            armed = false;
        }
        if let Some(d) = test_depth {
            if depth <= d {
                test_depth = None;
            }
        }
        raw.push(line.to_string());
        code.push(c);
        comment.push(com);
    }
    FileModel { raw, code, comment, in_test }
}

/// Byte offsets of `word` in `code` at identifier boundaries (so
/// `unsafe` does not match `unsafe_op_in_unsafe_fn`, `Mutex` does not
/// match `OrderedMutex`).
fn word_hits(code: &str, word: &str) -> Vec<usize> {
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut hits = Vec::new();
    let mut start = 0;
    while let Some(k) = code[start..].find(word) {
        let at = start + k;
        let pre_ok = code[..at].chars().next_back().map_or(true, |c| !is_ident(c));
        let post_ok = code[at + word.len()..].chars().next().map_or(true, |c| !is_ident(c));
        if pre_ok && post_ok {
            hits.push(at);
        }
        start = at + word.len();
    }
    hits
}

/// Walk the comment/attribute block directly above line `i` looking for
/// a comment containing `needle`. Stops at the first code line or blank
/// non-comment line.
fn adjacent_comment_contains(m: &FileModel, i: usize, needle: &str) -> bool {
    if m.comment[i].contains(needle) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        if m.comment[j].contains(needle) {
            return true;
        }
        let cj = m.code[j].trim();
        if m.comment[j].is_empty() && cj.is_empty() {
            return false; // blank line ends the block
        }
        if !cj.is_empty() && !cj.starts_with("#[") {
            return false; // real code ends the block
        }
    }
    false
}

// ---------------------------------------------------------------------
// The rules
// ---------------------------------------------------------------------

const BANNED_PANICS: [&str; 6] =
    [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

const ALLOC_TOKENS: [&str; 10] = [
    "Vec::new",
    "vec![",
    "Box::new(",
    ".to_vec()",
    "format!(",
    ".clone()",
    "String::new",
    ".to_string()",
    "with_capacity(",
    ".collect()",
];

const SERVING_DIRS: [&str; 3] = ["coordinator/", "traffic/", "engine/"];

const LOCK_ORDER_FILES: [&str; 3] =
    ["coordinator/server.rs", "coordinator/session.rs", "coordinator/tenants.rs"];

/// Rule 1: `unsafe` blocks / impls need an adjacent `// SAFETY:`.
fn check_unsafe_safety(rel: &str, m: &FileModel, out: &mut Vec<String>) {
    for (i, c) in m.code.iter().enumerate() {
        for k in word_hits(c, "unsafe") {
            let after = c[k + "unsafe".len()..].trim_start();
            if after.starts_with("fn ") {
                continue; // declaration: the obligation is the caller's
            }
            if !adjacent_comment_contains(m, i, "SAFETY:") {
                out.push(format!(
                    "{rel}:{}: `unsafe` without an adjacent `// SAFETY:` comment",
                    i + 1
                ));
            }
        }
    }
}

/// Rule 2: serving-path code must not contain panic tokens.
fn check_banned_panics(rel: &str, m: &FileModel, out: &mut Vec<String>) {
    if !SERVING_DIRS.iter().any(|d| rel.starts_with(d)) {
        return;
    }
    for (i, c) in m.code.iter().enumerate() {
        if m.in_test[i] {
            continue;
        }
        for t in BANNED_PANICS {
            if c.contains(t) {
                out.push(format!(
                    "{rel}:{}: `{t}` in serving-path code (answer with a typed EngineError)",
                    i + 1
                ));
            }
        }
    }
}

/// Rule 3: no heap allocation between hot-path fences; fences balance.
fn check_hot_path_fences(rel: &str, m: &FileModel, out: &mut Vec<String>) {
    let mut open: Option<usize> = None;
    for i in 0..m.raw.len() {
        let com = &m.comment[i];
        if com.contains("// hot-path: alloc-free") {
            if let Some(o) = open {
                out.push(format!(
                    "{rel}:{}: nested hot-path fence (previous opened at line {})",
                    i + 1,
                    o + 1
                ));
            }
            open = Some(i);
            continue;
        }
        if com.contains("// hot-path: end") {
            if open.is_none() {
                out.push(format!("{rel}:{}: `// hot-path: end` without an open fence", i + 1));
            }
            open = None;
            continue;
        }
        if let Some(o) = open {
            for t in ALLOC_TOKENS {
                if m.code[i].contains(t) {
                    out.push(format!(
                        "{rel}:{}: heap-allocating `{t}` inside hot-path fence opened at line {}",
                        i + 1,
                        o + 1
                    ));
                }
            }
        }
    }
    if let Some(o) = open {
        out.push(format!("{rel}:{}: unclosed `// hot-path: alloc-free` fence", o + 1));
    }
}

/// Rule 4: lock-order-audited files must not name raw sync primitives.
fn check_raw_sync(rel: &str, m: &FileModel, out: &mut Vec<String>) {
    if !LOCK_ORDER_FILES.contains(&rel) {
        return;
    }
    for (i, c) in m.code.iter().enumerate() {
        if m.in_test[i] {
            continue;
        }
        for prim in ["Mutex", "RwLock", "Condvar"] {
            if !word_hits(c, prim).is_empty() {
                out.push(format!(
                    "{rel}:{}: raw `std::sync::{prim}` in a lock-order-audited file \
                     (use `util::dbc::Ordered{prim}` so acquisitions are rank-checked)",
                    i + 1
                ));
            }
        }
    }
}

/// Rule 5: `#[allow]` outside tests needs an adjacent `// allow:`.
fn check_allow_justified(rel: &str, m: &FileModel, out: &mut Vec<String>) {
    for (i, c) in m.code.iter().enumerate() {
        if m.in_test[i] {
            continue;
        }
        let s = c.trim();
        let is_allow = s.starts_with("#[allow(")
            || s.starts_with("#![allow(")
            || (s.starts_with("#[cfg_attr(") && s.contains("allow("));
        if is_allow && !adjacent_comment_contains(m, i, "allow:") {
            out.push(format!(
                "{rel}:{}: `#[allow]` without an adjacent `// allow:` justification",
                i + 1
            ));
        }
    }
}

/// Rule 6a: every `rank::NAME` usage resolves in the dbc rank table.
fn check_rank_usages(rel: &str, m: &FileModel, ranks: &[(String, u16)], out: &mut Vec<String>) {
    for (i, c) in m.code.iter().enumerate() {
        let mut rest = c.as_str();
        while let Some(k) = rest.find("rank::") {
            rest = &rest[k + "rank::".len()..];
            let name: String = rest
                .chars()
                .take_while(|ch| ch.is_ascii_uppercase() || ch.is_ascii_digit() || *ch == '_')
                .collect();
            if !name.is_empty()
                && name.chars().next().is_some_and(|ch| ch.is_ascii_uppercase())
                && !ranks.iter().any(|(n, _)| *n == name)
            {
                out.push(format!(
                    "{rel}:{}: `rank::{name}` is not declared in the util/dbc.rs rank table",
                    i + 1
                ));
            }
        }
    }
}

/// Parse `pub const NAME: u16 = N;` declarations out of `util/dbc.rs`.
fn parse_rank_table(dbc_src: &str) -> Vec<(String, u16)> {
    let mut ranks = Vec::new();
    for line in dbc_src.lines() {
        let s = line.trim();
        let Some(rest) = s.strip_prefix("pub const ") else { continue };
        let Some((name, tail)) = rest.split_once(": u16 = ") else { continue };
        let Some(val) = tail.trim_end_matches(';').trim().parse::<u16>().ok() else { continue };
        ranks.push((name.to_string(), val));
    }
    ranks
}

/// Run every rule over one file.
fn lint_source(rel: &str, src: &str, ranks: &[(String, u16)]) -> Vec<String> {
    let m = analyze(src);
    let mut out = Vec::new();
    check_unsafe_safety(rel, &m, &mut out);
    check_banned_panics(rel, &m, &mut out);
    check_hot_path_fences(rel, &m, &mut out);
    check_raw_sync(rel, &m, &mut out);
    check_allow_justified(rel, &m, &mut out);
    check_rank_usages(rel, &m, ranks, &mut out);
    out
}

// ---------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src")
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = fs::read_dir(dir).unwrap_or_else(|e| panic!("read_dir {dir:?}: {e}"));
    for entry in entries {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    out.sort();
}

/// The tier-1 gate: the real source tree is clean under every rule.
#[test]
fn source_tree_is_clean() {
    let root = src_root();
    let dbc = fs::read_to_string(root.join("util/dbc.rs")).expect("util/dbc.rs");
    let ranks = parse_rank_table(&dbc);
    assert!(
        ranks.len() >= 8,
        "rank table parse broke: found only {} consts in util/dbc.rs",
        ranks.len()
    );
    // Rule 6b: declared ranks must be unique.
    let mut violations: Vec<String> = Vec::new();
    for (i, (na, va)) in ranks.iter().enumerate() {
        for (nb, vb) in &ranks[i + 1..] {
            if va == vb {
                violations
                    .push(format!("util/dbc.rs: rank::{na} and rank::{nb} share rank {va}"));
            }
        }
    }
    let mut files = Vec::new();
    rust_files(&root, &mut files);
    assert!(files.len() > 30, "expected a full tree walk, found {} files", files.len());
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .expect("under src root")
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"));
        violations.extend(lint_source(&rel, &src, &ranks));
    }
    assert!(
        violations.is_empty(),
        "static analysis found {} violation(s):\n{}",
        violations.len(),
        violations.join("\n")
    );
}

// ---------------------------------------------------------------------
// Self-test fixtures: each rule must fire on a seeded violation and
// stay quiet on the corrected twin.
// ---------------------------------------------------------------------

#[cfg(test)]
mod fixtures {
    use super::*;

    fn ranks() -> Vec<(String, u16)> {
        vec![("INJECTOR".to_string(), 40), ("QUOTA".to_string(), 45)]
    }

    fn lint(rel: &str, src: &str) -> Vec<String> {
        lint_source(rel, src, &ranks())
    }

    #[test]
    fn unsafe_without_safety_fires() {
        let bad = "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let v = lint("sim/fixture.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("SAFETY"), "{v:?}");

        let good = "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is \
                    valid for reads.\n    unsafe { *p }\n}\n";
        assert!(lint("sim/fixture.rs", good).is_empty());
        // unsafe fn declarations are exempt — obligation is on callers
        let decl = "pub unsafe fn f(p: *const u8) -> u8 {\n    // SAFETY: p valid.\n    \
                    unsafe { *p }\n}\n";
        assert!(lint("sim/fixture.rs", decl).is_empty());
    }

    #[test]
    fn safety_in_string_literal_does_not_count() {
        let sneaky = "pub fn f(p: *const u8) -> u8 {\n    let _m = \"// SAFETY: lies\";\n    \
                      let _x = 1;\n    unsafe { *p }\n}\n";
        let v = lint("sim/fixture.rs", sneaky);
        assert_eq!(v.len(), 1, "string-literal SAFETY must not satisfy the rule: {v:?}");
    }

    #[test]
    fn serving_path_panic_tokens_fire() {
        for (tok, src) in [
            ("unwrap", "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n"),
            ("expect", "fn f(x: Option<u8>) -> u8 {\n    x.expect(\"boom\")\n}\n"),
            ("panic!", "fn f() {\n    panic!(\"boom\")\n}\n"),
            ("unreachable!", "fn f() {\n    unreachable!()\n}\n"),
        ] {
            let v = lint("coordinator/fixture.rs", src);
            assert_eq!(v.len(), 1, "token {tok}: {v:?}");
            // the same code is fine outside the serving dirs
            assert!(lint("sim/fixture.rs", src).is_empty(), "token {tok}");
        }
    }

    #[test]
    fn cfg_test_regions_are_exempt_from_panic_ban() {
        let src = "pub fn ok() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
                   Some(1).unwrap();\n        panic!(\"fine in tests\");\n    }\n}\n";
        assert!(lint("coordinator/fixture.rs", src).is_empty());
        // ...but code after the test module is covered again
        let trailing = format!("{src}\npub fn bad(x: Option<u8>) -> u8 {{\n    x.unwrap()\n}}\n");
        assert_eq!(lint("coordinator/fixture.rs", &trailing).len(), 1);
    }

    #[test]
    fn panic_token_in_string_or_comment_is_ignored() {
        let src = "fn f() -> &'static str {\n    // a panic!(...) here is just prose\n    \
                   \"worker panic!(simulated)\"\n}\n";
        assert!(lint("coordinator/fixture.rs", src).is_empty());
    }

    #[test]
    fn hot_path_alloc_tokens_fire() {
        let bad = "// hot-path: alloc-free (fixture)\nfn f() -> Vec<u8> {\n    Vec::new()\n}\n\
                   // hot-path: end\n";
        let v = lint("sim/fixture.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("Vec::new"), "{v:?}");

        let good = "// hot-path: alloc-free (fixture)\nfn f(buf: &mut Vec<u8>) {\n    \
                    buf.push(1);\n}\n// hot-path: end\nfn warmup() -> Vec<u8> {\n    \
                    Vec::new()\n}\n";
        assert!(lint("sim/fixture.rs", good).is_empty());
    }

    #[test]
    fn unbalanced_fences_fire() {
        let unclosed = "// hot-path: alloc-free (fixture)\nfn f() {}\n";
        let v = lint("sim/fixture.rs", unclosed);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("unclosed"), "{v:?}");

        let stray = "fn f() {}\n// hot-path: end\n";
        let v = lint("sim/fixture.rs", stray);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("without an open fence"), "{v:?}");
    }

    #[test]
    fn raw_sync_primitives_fire_in_lock_order_files() {
        let bad = "use std::sync::Mutex;\nstruct S {\n    m: Mutex<u32>,\n}\n";
        let v = lint("coordinator/server.rs", bad);
        assert_eq!(v.len(), 2, "one per mention: {v:?}");
        // Ordered wrappers never match — word-boundary scan
        let good = "use crate::util::dbc::{OrderedCondvar, OrderedMutex, OrderedRwLock};\n\
                    struct S {\n    m: OrderedMutex<u32>,\n    r: OrderedRwLock<u32>,\n    \
                    c: OrderedCondvar,\n}\n";
        assert!(lint("coordinator/server.rs", good).is_empty());
        // other files may use raw primitives (dbc itself wraps them)
        assert!(lint("util/fixture.rs", bad).is_empty());
    }

    #[test]
    fn unjustified_allow_fires() {
        let bad = "#[allow(clippy::too_many_arguments)]\nfn f() {}\n";
        let v = lint("sim/fixture.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("allow"), "{v:?}");

        let good = "// allow: fixture reason spelled out here.\n\
                    #[allow(clippy::too_many_arguments)]\nfn f() {}\n";
        assert!(lint("sim/fixture.rs", good).is_empty());

        let cfg_attr = "#[cfg_attr(not(feature = \"x\"), allow(dead_code))]\nfn f() {}\n";
        assert_eq!(lint("sim/fixture.rs", cfg_attr).len(), 1);
    }

    #[test]
    fn unknown_rank_fires_and_known_rank_passes() {
        let bad = "fn f() -> u16 {\n    rank::NOT_A_REAL_RANK\n}\n";
        let v = lint("coordinator/fixture.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("NOT_A_REAL_RANK"), "{v:?}");

        let good = "fn f() -> u16 {\n    rank::INJECTOR + rank::QUOTA\n}\n";
        assert!(lint("coordinator/fixture.rs", good).is_empty());
    }

    #[test]
    fn rank_table_parser_reads_real_declarations() {
        let snippet = "/// Tenant registry.\npub const TENANT_REGISTRY: u16 = 10;\n\
                       pub const INJECTOR: u16 = 40;\nconst PRIVATE: u16 = 1;\n";
        let ranks = parse_rank_table(snippet);
        assert_eq!(
            ranks,
            vec![("TENANT_REGISTRY".to_string(), 10), ("INJECTOR".to_string(), 40)]
        );
    }
}
