//! Proof that the §Perf compile/execute split holds: after warm-up, the
//! accelerator's execute step (`infer_image_into`) performs ZERO heap
//! allocations, and `infer_image` allocates only the returned
//! `Inference`'s own small output vectors — never per-event traffic.
//!
//! This file contains exactly one test: the `#[global_allocator]`
//! counter is process-wide, so concurrent tests in the same binary would
//! pollute the measurement.

use sacsnn::engine::Inference;
use sacsnn::sim::{AccelConfig, Accelerator};
use sacsnn::snn::network::testutil::random_network;
use sacsnn::util::alloc_counter::{alloc_count as allocs, CountingAllocator};
use sacsnn::util::prng::Pcg;
use std::sync::Arc;

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

#[test]
fn steady_state_inference_is_allocation_free() {
    let net = Arc::new(random_network(90));
    let (h, w, c) = net.input_shape();
    let mut rng = Pcg::new(17);
    let imgs: Vec<Vec<u8>> = (0..3)
        .map(|_| (0..h * w * c).map(|_| rng.below(256) as u8).collect())
        .collect();
    let bright = vec![250u8; h * w * c]; // maximum input spikes

    let mut accel = Accelerator::new(Arc::clone(&net), AccelConfig::default());
    let mut out = Inference::default();

    // Warm-up: grow every scratch buffer and output vector to the
    // high-water mark of this workload.
    for _ in 0..3 {
        accel.infer_image_into(&bright, &mut out);
        for img in &imgs {
            accel.infer_image_into(img, &mut out);
        }
    }

    // Steady state: the execute step must not touch the allocator.
    let before = allocs();
    for _ in 0..5 {
        accel.infer_image_into(&bright, &mut out);
        for img in &imgs {
            accel.infer_image_into(img, &mut out);
        }
    }
    let grew = allocs() - before;
    assert_eq!(grew, 0, "steady-state infer_image_into allocated {grew} times");

    // `infer_image` adds only the returned Inference's output vectors —
    // an O(layers + t_steps) constant, nothing per-event (the pre-plan
    // path allocated thousands of times per inference here).
    let before = allocs();
    let res = accel.infer_image(&imgs[0]);
    let per_infer = allocs() - before;
    assert!(
        per_infer <= 64,
        "infer_image allocated {per_infer} times; expected only the output container"
    );

    // The recycled path must still be bit-identical to a fresh machine.
    let mut fresh = Accelerator::new(Arc::clone(&net), AccelConfig::default());
    let want = fresh.infer_image(&imgs[0]);
    assert_eq!(res.logits, want.logits);
    assert_eq!(res.stats.total_cycles, want.stats.total_cycles);
    accel.infer_image_into(&imgs[0], &mut out);
    assert_eq!(out.logits, want.logits);
    assert_eq!(out.stats.spike_counts, want.stats.spike_counts);
}
