//! Proof that the §Perf compile/execute split holds: after warm-up, the
//! accelerator's execute step (`infer_image_into`) performs ZERO heap
//! allocations, and `infer_image` allocates only the returned
//! `Inference`'s own small output vectors — never per-event traffic.
//! The batched path inherits the property **per worker**: a warmed
//! `ShardedExecutor` running a constant-size `infer_batch` on its
//! worker loop allocates nothing either (spawning OS threads is the
//! only allocating step of a multi-thread dispatch, which is why the
//! proof drives the single-worker inline path — the per-worker loop is
//! the same code the spawned shards run).
//!
//! The serving layer inherits the property end to end: a warmed
//! multi-tenant `Server` session (feed → injector → persistent worker →
//! `infer_stream` → reorder ring → `recv_into` swap) adds ZERO
//! allocations per frame — frames copy into pooled containers, results
//! ride recycled response slots, and the worker hands each output
//! container straight back to the backend. The measured tenant runs
//! with the self-healing supervision armed (a watchdog-scanned dispatch
//! deadline and a nonzero retry budget whose per-frame retry copies
//! ride the same frame pool), so healthy-path fault readiness is free.
//!
//! This file contains exactly one test: the `#[global_allocator]`
//! counter is process-wide, so concurrent tests in the same binary would
//! pollute the measurement.

use sacsnn::coordinator::{Response, Server, ServerConfig, Session, TenantConfig};
use sacsnn::engine::{EngineError, Frame, Inference};
use sacsnn::sim::{AccelConfig, Accelerator, PipelinedExecutor, ShardedExecutor};
use sacsnn::snn::network::testutil::random_network;
use sacsnn::util::alloc_counter::{alloc_count as allocs, CountingAllocator};
use sacsnn::util::prng::Pcg;
use std::sync::Arc;

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

/// Feed `k` copies of `frame` into the session and drain every result
/// through the caller-recycled `resp` container; returns how many
/// results were received. Allocation-free once everything is warm —
/// which is why this mirrors `Session::feed_yielding` by hand through
/// `recv_into` instead of calling it (the helper's `recv()` allocates
/// a fresh Response per result and would pollute the measurement).
fn pump_session(session: &mut Session, frame: &Frame, resp: &mut Response, k: usize) -> usize {
    let mut served = 0;
    for _ in 0..k {
        loop {
            match session.feed(frame) {
                Ok(_) => break,
                Err(EngineError::TenantOverQuota { .. }) => {
                    if let Some(r) = session.recv_into(resp) {
                        r.unwrap();
                        served += 1;
                    }
                }
                Err(e) => panic!("unexpected feed error: {e}"),
            }
        }
    }
    while let Some(r) = session.recv_into(resp) {
        r.unwrap();
        served += 1;
    }
    served
}

#[test]
fn steady_state_inference_is_allocation_free() {
    let net = Arc::new(random_network(90));
    let (h, w, c) = net.input_shape();
    let mut rng = Pcg::new(17);
    let imgs: Vec<Vec<u8>> = (0..3)
        .map(|_| (0..h * w * c).map(|_| rng.below(256) as u8).collect())
        .collect();
    let bright = vec![250u8; h * w * c]; // maximum input spikes

    let mut accel = Accelerator::new(Arc::clone(&net), AccelConfig::default());
    let mut out = Inference::default();

    // Warm-up: grow every scratch buffer and output vector to the
    // high-water mark of this workload.
    for _ in 0..3 {
        accel.infer_image_into(&bright, &mut out);
        for img in &imgs {
            accel.infer_image_into(img, &mut out);
        }
    }

    // Steady state: the execute step must not touch the allocator.
    let before = allocs();
    for _ in 0..5 {
        accel.infer_image_into(&bright, &mut out);
        for img in &imgs {
            accel.infer_image_into(img, &mut out);
        }
    }
    let grew = allocs() - before;
    assert_eq!(grew, 0, "steady-state infer_image_into allocated {grew} times");

    // `infer_image` adds only the returned Inference's output vectors —
    // an O(layers + t_steps) constant, nothing per-event (the pre-plan
    // path allocated thousands of times per inference here).
    let before = allocs();
    let res = accel.infer_image(&imgs[0]);
    let per_infer = allocs() - before;
    assert!(
        per_infer <= 64,
        "infer_image allocated {per_infer} times; expected only the output container"
    );

    // The recycled path must still be bit-identical to a fresh machine.
    let mut fresh = Accelerator::new(Arc::clone(&net), AccelConfig::default());
    let want = fresh.infer_image(&imgs[0]);
    assert_eq!(res.logits, want.logits);
    assert_eq!(res.stats.total_cycles, want.stats.total_cycles);
    accel.infer_image_into(&imgs[0], &mut out);
    assert_eq!(out.logits, want.logits);
    assert_eq!(out.stats.spike_counts, want.stats.spike_counts);

    // ---- batched path: the per-worker loop of the sharded executor ----
    // A warmed executor running a constant-size batch must not touch the
    // allocator either: the output vec is recycled by resize_batch_out,
    // each slot by infer_image_into, and the shape pre-check is
    // allocation-free. (One worker → the inline path, i.e. exactly the
    // chase-the-queue body without the thread spawns.)
    let frames: Vec<Frame> = imgs
        .iter()
        .chain(std::iter::once(&bright))
        .map(|img| Frame::from_u8(h, w, c, img.clone()).unwrap())
        .collect();
    let mut pool = ShardedExecutor::new(Arc::clone(&net), AccelConfig::default(), 1);
    let mut batch_out = Vec::new();
    for _ in 0..3 {
        pool.infer_batch_into(&frames, &mut batch_out).unwrap(); // warm-up
    }
    let before = allocs();
    for _ in 0..5 {
        pool.infer_batch_into(&frames, &mut batch_out).unwrap();
    }
    let grew = allocs() - before;
    assert_eq!(grew, 0, "steady-state batched infer allocated {grew} times");
    assert_eq!(batch_out.len(), frames.len());
    assert_eq!(batch_out[0].logits, want.logits, "batched worker must stay bit-exact");

    // The multi-thread dispatch allocates only for the thread spawns —
    // never per event: with 2 workers and a 4-frame batch, the whole
    // dispatch must stay well under the pre-plan path's thousands of
    // per-event allocations. Warm-up must be deterministic: the
    // chase-the-queue cursor gives no guarantee which worker saw which
    // frame, so `warm` runs every frame on EVERY worker inline.
    let mut pool2 = ShardedExecutor::new(Arc::clone(&net), AccelConfig::default(), 2);
    for frame in &frames {
        pool2.warm(frame).unwrap();
    }
    pool2.infer_batch_into(&frames, &mut batch_out).unwrap(); // size batch_out
    let before = allocs();
    pool2.infer_batch_into(&frames, &mut batch_out).unwrap();
    let spawn_overhead = allocs() - before;
    assert!(
        spawn_overhead <= 64,
        "multi-thread dispatch allocated {spawn_overhead} times; \
         expected only thread-spawn bookkeeping"
    );

    // ---- self-timed pipeline: stage workers at steady state ----
    // A stream call has a fixed O(depth) dispatch cost (scoped stage
    // threads + bounded channels), but the warmed stage workers, slab
    // rotation and output recycling must be allocation-free PER FRAME.
    // The proof is marginal cost: with every container warmed, a stream
    // of 2N identical frames must allocate exactly as much as a stream
    // of N — i.e. the extra N frames cost zero allocations. Identical
    // frames make the measurement rotation-proof: slabs and output
    // containers circulate nondeterministically (completion timing
    // decides which slab serves which frame), but every container sees
    // the same high-water marks.
    let bright_frame = Frame::from_u8(h, w, c, bright.clone()).unwrap();
    let small: Vec<Frame> = vec![bright_frame.clone(); 8];
    let large: Vec<Frame> = vec![bright_frame.clone(); 16];
    let mut pipe = PipelinedExecutor::new(Arc::clone(&net), AccelConfig::default(), usize::MAX);
    let (mut out_small, mut out_large) = (Vec::new(), Vec::new());
    // `warm` pushes the frame through EVERY slab and stage buffer
    // deterministically; the stream rounds then warm the two output vecs
    // and exercise the rotation itself.
    pipe.warm(&bright_frame).unwrap();
    for _ in 0..3 {
        pipe.run_stream_into(&large, &mut out_large).unwrap();
        pipe.run_stream_into(&small, &mut out_small).unwrap();
    }
    let before = allocs();
    pipe.run_stream_into(&small, &mut out_small).unwrap();
    let cost_small = allocs() - before;
    let before = allocs();
    pipe.run_stream_into(&large, &mut out_large).unwrap();
    let cost_large = allocs() - before;
    assert_eq!(
        cost_large, cost_small,
        "8 extra streamed frames allocated {} times — warmed stage workers \
         must be allocation-free per frame",
        cost_large as i64 - cost_small as i64
    );
    assert!(
        cost_small <= 96,
        "pipeline dispatch allocated {cost_small} times; expected only \
         O(depth) thread-spawn + channel bookkeeping"
    );
    // and the streamed results are still bit-exact
    let bright_want = {
        let mut fresh = Accelerator::new(Arc::clone(&net), AccelConfig::default());
        fresh.infer_image(&bright)
    };
    assert_eq!(out_large.len(), 16);
    for inf in &out_large {
        assert_eq!(inf.logits, bright_want.logits, "pipelined result must stay bit-exact");
        assert_eq!(inf.stats, bright_want.stats);
    }

    // ---- multi-tenant serving: the warmed persistent-pool session path ----
    // The full serving loop — Session::feed (frame copy into a pooled
    // container), injector queue, persistent worker, infer_stream with
    // the container round trip, reorder-ring delivery, recv_into swap —
    // must add ZERO allocations per frame once warm. The proof is the
    // same marginal-cost argument as the pipeline section: pumping 16
    // frames must allocate exactly as much as pumping 8 (identical
    // frames make the measurement pool/ring-rotation-proof).
    let server = Server::start(ServerConfig {
        workers: 1,
        batch_size: 8,
        ..Default::default()
    })
    .unwrap();
    let tenant = server
        .register_tenant(
            Arc::clone(&net),
            // lanes: 1 matches the AccelConfig::default() reference run,
            // so sim_cycles can be compared exactly below. The generous
            // dispatch deadline and nonzero retry budget arm the full
            // supervision machinery — watchdog-scanned slot deadlines,
            // per-frame retry copies riding the tenant frame pool — and
            // the marginal-cost assertions below prove it allocation-free
            // on the healthy path.
            TenantConfig {
                max_inflight: 32,
                lanes: 1,
                dispatch_timeout: std::time::Duration::from_secs(5),
                max_retries: 1,
                ..Default::default()
            },
        )
        .unwrap();
    let mut session = server.open_session(tenant).unwrap();
    let mut resp = Response::default();
    // Warm with a LARGER burst (24) than anything measured (8/16): the
    // frame pool, injector queue and reorder ring reach a strictly
    // higher high-water mark than any measured run can demand, so
    // scheduling variance (how fast the worker drains during a feed
    // burst) cannot make the measured window grow a container.
    for _ in 0..3 {
        assert_eq!(pump_session(&mut session, &bright_frame, &mut resp, 24), 24);
        assert_eq!(pump_session(&mut session, &bright_frame, &mut resp, 16), 16);
        assert_eq!(pump_session(&mut session, &bright_frame, &mut resp, 8), 8);
    }
    let before = allocs();
    let served_small = pump_session(&mut session, &bright_frame, &mut resp, 8);
    let session_cost_small = allocs() - before;
    let before = allocs();
    let served_large = pump_session(&mut session, &bright_frame, &mut resp, 16);
    let session_cost_large = allocs() - before;
    assert_eq!(served_small, 8);
    assert_eq!(served_large, 16);
    assert_eq!(
        session_cost_large, session_cost_small,
        "8 extra session frames allocated {} times — the warmed serving \
         path must add zero allocations per frame",
        session_cost_large as i64 - session_cost_small as i64
    );
    assert!(
        session_cost_small <= 32,
        "session pump allocated {session_cost_small} times; the warmed \
         persistent-pool path should not touch the allocator"
    );
    // served results stay bit-exact through all the recycling
    assert_eq!(resp.logits, bright_want.logits, "session result must stay bit-exact");
    assert_eq!(resp.sim_cycles, bright_want.stats.total_cycles);
    server.shutdown();

    // ---- dbc lock-order shadow detector: zero release-build cost ----
    // Every serving lock above already went through `util::dbc`, so the
    // marginal-cost proofs cover it implicitly; this section pins the
    // instrumentation itself. In release builds the rank bookkeeping
    // compiles to nothing (`HeldToken` is a ZST with no Drop), so a
    // lock/invariant/condvar cycle must not touch the allocator at all.
    // Debug builds keep the per-thread rank stack on the heap; after the
    // warm-up push has grown it, the loop stays within capacity.
    use sacsnn::util::dbc::{rank, OrderedCondvar, OrderedMutex};
    let probe = OrderedMutex::new(rank::METRICS, "dbc-probe", 0u64);
    let probe_cv = OrderedCondvar::new();
    {
        // warm-up: first acquisition grows the debug rank stack
        let mut g = sacsnn::ordered_lock!(probe);
        *g += 1;
        let (g, _timed_out) = probe_cv.wait_timeout(g, std::time::Duration::from_micros(100));
        drop(g);
    }
    let before = allocs();
    for i in 0..1000u64 {
        let mut g = sacsnn::ordered_lock!(probe);
        *g = g.wrapping_add(i);
        sacsnn::debug_invariant!(*g >= i, "probe counter went backwards");
    }
    for _ in 0..20 {
        let g = sacsnn::ordered_lock!(probe);
        let (g, _timed_out) = probe_cv.wait_timeout(g, std::time::Duration::from_micros(100));
        drop(g);
    }
    let dbc_grew = allocs() - before;
    if cfg!(debug_assertions) {
        assert!(dbc_grew <= 8, "debug dbc bookkeeping allocated {dbc_grew} times after warm-up");
    } else {
        assert_eq!(dbc_grew, 0, "release-build dbc instrumentation allocated {dbc_grew} times");
    }
}
