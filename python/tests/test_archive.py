"""Interchange-format tests: the tensor archive writer/reader round trip
(the Rust reader is tested against the same spec on its side)."""

import numpy as np
import pytest

from compile import archive


def test_roundtrip_all_dtypes(tmp_path):
    path = str(tmp_path / "t.bin")
    tensors = {
        "f": np.linspace(-1, 1, 24, dtype=np.float32).reshape(2, 3, 4),
        "i": np.arange(-5, 5, dtype=np.int32),
        "s": np.asarray([-300, 300], np.int16),
        "b": np.asarray([-8, 7], np.int8),
        "u": np.arange(10, dtype=np.uint8).reshape(2, 5),
    }
    archive.write_archive(path, tensors)
    back = archive.read_archive(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        archive.write_archive(str(tmp_path / "x.bin"), {"d": np.zeros(2, np.float64)})


def test_empty_archive(tmp_path):
    path = str(tmp_path / "e.bin")
    archive.write_archive(path, {})
    assert archive.read_archive(path) == {}


def test_scalarish_shapes(tmp_path):
    path = str(tmp_path / "s.bin")
    archive.write_archive(path, {"one": np.asarray([42.0], np.float32)})
    back = archive.read_archive(path)
    assert back["one"].shape == (1,)
    assert back["one"][0] == 42.0


def test_unicode_names(tmp_path):
    path = str(tmp_path / "u.bin")
    archive.write_archive(path, {"poids_couche_été": np.zeros(3, np.float32)})
    assert "poids_couche_été" in archive.read_archive(path)
