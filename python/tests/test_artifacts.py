"""Build-output sanity: if `artifacts/` exists, its contents must be
mutually consistent (these are what the Rust binary consumes)."""

import json
import os

import numpy as np
import pytest

from compile import archive

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def meta():
    with open(os.path.join(ART, "meta.json")) as f:
        return json.load(f)


def test_meta_schema(meta):
    assert meta["t_steps"] == 5
    assert len(meta["thresholds"]) == 5
    assert sorted(meta["thresholds"]) == meta["thresholds"], "strictly increasing"
    for ds in ("mnist", "fashion"):
        for bits in (8, 16):
            q = meta["quant"][f"{ds}_q{bits}"]
            assert q["bits"] == bits
            assert len(q["scales"]) == 3
            assert len(q["vt_q"]) == 3
            assert q["sat_max"] == 2 ** (q["acc_bits"] - 1) - 1


@pytest.mark.parametrize("name", ["weights_q8.bin", "weights_q16.bin",
                                  "weights_q8_fashion.bin", "weights_f32.bin"])
def test_weight_archives_consistent(name, meta):
    ar = archive.read_archive(os.path.join(ART, name))
    assert ar["conv0_w"].shape == (3, 3, 1, 32)
    assert ar["conv1_w"].shape == (3, 3, 32, 32)
    assert ar["conv2_w"].shape == (3, 3, 32, 10)
    assert ar["fc_w"].shape == (360, 10)
    assert ar["thresholds"].shape == (5,)
    if "q8" in name:
        assert ar["conv0_w"].dtype == np.int32
        assert np.abs(ar["conv0_w"]).max() <= 127
    if "q16" in name:
        assert np.abs(ar["conv0_w"]).max() <= 2**15 - 1


def test_vt_matches_meta(meta):
    ar = archive.read_archive(os.path.join(ART, "weights_q8.bin"))
    for i in range(3):
        assert float(ar[f"conv{i}_vt"][0]) == meta["quant"]["mnist_q8"]["vt_q"][i]


@pytest.mark.parametrize("name", ["mnist.bin", "fashion.bin"])
def test_datasets(name, meta):
    ds = archive.read_archive(os.path.join(ART, name))
    n_train = meta["datasets"]["n_train"]
    n_test = meta["datasets"]["n_test"]
    assert ds["train_x"].shape == (n_train, 28, 28)
    assert ds["test_x"].shape == (n_test, 28, 28)
    assert ds["train_y"].shape == (n_train,)
    assert len(np.unique(ds["test_y"])) == 10


def test_hlo_artifacts_have_full_constants():
    # the regression that broke the golden check: elided `{...}` constants
    for name in ("model_q8.hlo.txt", "model_q16.hlo.txt", "layer_step.hlo.txt"):
        text = open(os.path.join(ART, name)).read()
        assert "{...}" not in text, f"{name} has elided constants"
        assert "ENTRY" in text


def test_build_accuracy_recorded(meta):
    acc = meta["accuracy"]["mnist"]
    # the model must have actually learned something at build time
    assert acc["ann"] > 0.9
    assert acc["snn_q8"] > 0.85
