"""L2 model tests: shapes, m-TTFS invariants, pallas/ref path agreement,
training/conversion/quantization machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, train
from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    """Small random (untrained) CSNN in float domain."""
    w = train.init_weights(0)
    snn = train.convert_to_snn(
        w, (np.random.default_rng(0).random((32, 28, 28)) * 255).astype(np.uint8)
    )
    return snn


@pytest.fixture(scope="module")
def frames():
    rng = np.random.default_rng(1)
    img = jnp.asarray(rng.random((28, 28)), jnp.float32)
    return ref.encode_mttfs(img, jnp.asarray(train.INPUT_THRESHOLDS))


class TestForward:
    def test_shapes(self, params, frames):
        logits, counts = M.csnn_forward(params, frames)
        assert logits.shape == (10,)
        assert counts.shape == (M.T_STEPS, 3)

    def test_pallas_and_ref_paths_agree(self, params, frames):
        l_ref, c_ref = M.csnn_forward(params, frames, use_pallas=False)
        l_pal, c_pal = M.csnn_forward(params, frames, use_pallas=True)
        np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref), rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(c_pal), np.asarray(c_ref))

    def test_spike_counts_monotone(self, params, frames):
        _, counts = M.csnn_forward(params, frames)
        counts = np.asarray(counts)
        for layer in range(3):
            assert np.all(np.diff(counts[:, layer]) >= 0), counts

    def test_blank_input_count_zero_layer1_only_bias(self, params):
        blank = jnp.zeros((M.T_STEPS, 28, 28, 1))
        _, counts = M.csnn_forward(params, blank)
        # with zero input, layer-1 can only fire from accumulated bias
        assert np.asarray(counts).shape == (M.T_STEPS, 3)


class TestQuantization:
    def test_integral_and_bounded(self, params):
        for bits in (8, 16):
            q, qi = train.quantize_snn(params, bits)
            qmax = 2 ** (bits - 1) - 1
            for layer in q.conv:
                w = np.asarray(layer.w)
                assert np.all(w == np.round(w)), "weights must be integral"
                assert np.abs(w).max() <= qmax
                assert float(layer.vt) == round(float(layer.vt))
            assert qi.acc_bits in (20, 24)
            assert q.sat_max == float(2 ** (qi.acc_bits - 1) - 1)

    def test_quantized_forward_is_integral(self, params, frames):
        q, _ = train.quantize_snn(params, 8)
        logits, _ = M.csnn_forward(q, frames)
        logits = np.asarray(logits)
        np.testing.assert_allclose(logits, np.round(logits))

    def test_q16_better_or_equal_fidelity(self, params):
        # q16 logits should be closer to float logits than q8 (relative)
        rng = np.random.default_rng(3)
        img = jnp.asarray(rng.random((28, 28)), jnp.float32)
        fr = ref.encode_mttfs(img, params.thresholds)
        lf, _ = M.csnn_forward(params, fr)
        pf = np.argsort(np.asarray(lf))
        q8, i8 = train.quantize_snn(params, 8)
        q16, i16 = train.quantize_snn(params, 16)
        l8, _ = M.csnn_forward(q8, fr)
        l16, _ = M.csnn_forward(q16, fr)
        r8 = np.asarray(l8) / i8.fc_scale
        r16 = np.asarray(l16) / i16.fc_scale
        # ranking of the top class is preserved by at least one of them
        assert np.argmax(r16) == pf[-1] or np.argmax(r8) == pf[-1]


class TestConversion:
    def test_normalization_leaves_vt_one(self, params):
        for layer in params.conv:
            assert layer.vt == 1.0 or layer.vt <= 1.0  # calibrate_vt may scale

    def test_ann_forward_shapes(self):
        w = train.init_weights(1)
        img = jnp.zeros((28, 28, 1))
        logits, acts = M.ann_forward(w, img)
        assert logits.shape == (10,)
        a1, a2, a2p, a3 = acts
        assert a1.shape == (26, 26, 32)
        assert a2.shape == (24, 24, 32)
        assert a2p.shape == (8, 8, 32)
        assert a3.shape == (6, 6, 10)

    def test_clamped_relu_range(self):
        w = train.init_weights(2)
        rng = np.random.default_rng(0)
        img = jnp.asarray(rng.random((28, 28, 1)), jnp.float32)
        _, acts = M.ann_forward(w, img)
        for a in acts[:2] + (acts[3],):
            assert float(jnp.min(a)) >= 0.0
            assert float(jnp.max(a)) <= 1.0


class TestData:
    def test_mnist_deterministic(self):
        a = data.synth_mnist(16, 7)
        b = data.synth_mnist(16, 7)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_mnist_seed_changes(self):
        a = data.synth_mnist(16, 7)[0]
        b = data.synth_mnist(16, 8)[0]
        assert not np.array_equal(a, b)

    def test_shapes_and_classes(self):
        for gen in (data.synth_mnist, data.synth_fashion):
            x, y = gen(64, 3)
            assert x.shape == (64, 28, 28)
            assert x.dtype == np.uint8
            assert y.shape == (64,)
            assert set(np.unique(y)).issubset(set(range(10)))

    def test_images_nontrivial(self):
        x, _ = data.synth_mnist(32, 5)
        # strokes present: some bright pixels, mostly dark background
        frac_bright = (x > 128).mean()
        assert 0.02 < frac_bright < 0.5
        xf, _ = data.synth_fashion(32, 5)
        assert (xf > 100).mean() > 0.05

    def test_mttfs_quantize_levels(self):
        imgs = np.linspace(0, 1, 100, dtype=np.float32).reshape(1, 10, 10)
        q = train.mttfs_quantize(imgs)
        # values are k/5 for k in 0..5 (compare in float32)
        levels = (np.arange(6) / 5.0).astype(np.float32)
        assert all(np.any(np.isclose(v, levels, atol=1e-6)) for v in np.unique(q))
