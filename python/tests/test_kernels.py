"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

THE core correctness signal of the compile path. Hypothesis sweeps
shapes, densities and threshold regimes; assert_allclose against ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.csnn_step import (
    if_layer_step_pallas,
    im2col_valid3,
    weights_to_matrix,
)
from compile.kernels.event_conv import event_conv_scatter, events_from_fmap

jax.config.update("jax_platform_name", "cpu")


def rand_layer(key, h, w, cin, cout, density=0.2):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    x = (jax.random.uniform(k1, (h, w, cin)) < density).astype(jnp.float32)
    wk = jax.random.normal(k2, (3, 3, cin, cout))
    b = jax.random.normal(k3, (cout,)) * 0.1
    vm = jax.random.normal(k4, (h - 2, w - 2, cout))
    fired = jax.random.uniform(k5, (h - 2, w - 2, cout)) > 0.9
    return x, wk, b, vm, fired


class TestIfLayerStep:
    @settings(max_examples=12, deadline=None)
    @given(
        h=st.integers(5, 16),
        w=st.integers(5, 16),
        cin=st.integers(1, 4),
        cout=st.sampled_from([2, 4, 8]),
        density=st.sampled_from([0.0, 0.1, 0.5, 1.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, h, w, cin, cout, density, seed):
        key = jax.random.PRNGKey(seed)
        x, wk, b, vm, fired = rand_layer(key, h, w, cin, cout, density)
        vt = 0.3
        s_ref, vm_ref, f_ref = ref.if_layer_step(x, wk, b, vm, fired, vt)
        s_p, vm_p, f_p = if_layer_step_pallas(
            x, weights_to_matrix(wk), b, vm, fired.astype(jnp.float32),
            vt=vt, block_cout=2,
        )
        np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(vm_p), np.asarray(vm_ref), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(f_p), np.asarray(f_ref).astype(np.float32), atol=1e-5
        )

    def test_saturation_clamps(self):
        key = jax.random.PRNGKey(0)
        x, wk, b, vm, fired = rand_layer(key, 8, 8, 2, 4, density=1.0)
        wk = wk * 100.0
        s_ref, vm_ref, _ = ref.if_layer_step(
            x, wk, b, vm, fired, 0.5, sat_min=-10.0, sat_max=10.0
        )
        s_p, vm_p, _ = if_layer_step_pallas(
            x, weights_to_matrix(wk), b, vm, fired.astype(jnp.float32),
            vt=0.5, sat_min=-10.0, sat_max=10.0, block_cout=4,
        )
        assert float(jnp.max(jnp.abs(vm_p))) <= 10.0
        np.testing.assert_allclose(np.asarray(vm_p), np.asarray(vm_ref), atol=1e-5)

    def test_mttfs_indicator_sticky(self):
        # once fired=1, output spike stays 1 even with inhibitory input
        key = jax.random.PRNGKey(1)
        x, wk, b, vm, _ = rand_layer(key, 6, 6, 1, 2, density=0.3)
        fired = jnp.ones((4, 4, 2), jnp.float32)
        s_p, _, f_p = if_layer_step_pallas(
            x, weights_to_matrix(wk) * 0.0 - 100.0, b * 0.0, vm * 0.0 - 100.0,
            fired, vt=0.5,
        )
        assert float(jnp.min(s_p)) == 1.0
        assert float(jnp.min(f_p)) == 1.0

    def test_zero_input_skips_update(self):
        # tile-sparsity predicate: zero spikes → membrane changes only by bias
        key = jax.random.PRNGKey(2)
        _, wk, b, vm, fired = rand_layer(key, 9, 9, 2, 4)
        x = jnp.zeros((9, 9, 2), jnp.float32)
        _, vm_p, _ = if_layer_step_pallas(
            x, weights_to_matrix(wk), b, vm, fired.astype(jnp.float32), vt=0.5,
            block_cout=4,
        )
        want = jnp.clip(vm + b[None, None, :], -3.0e38, 3.0e38)
        np.testing.assert_allclose(np.asarray(vm_p), np.asarray(want), atol=1e-5)

    def test_im2col_layout_matches_weight_matrix(self):
        # conv via im2col @ wm must equal lax conv for the SAME layout
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (7, 9, 3))
        wk = jax.random.normal(jax.random.PRNGKey(4), (3, 3, 3, 5))
        got = (im2col_valid3(x) @ weights_to_matrix(wk)).reshape(5, 7, 5)
        want = ref.valid_conv3(x, wk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


class TestEventConv:
    @settings(max_examples=12, deadline=None)
    @given(
        h=st.integers(4, 14),
        w=st.integers(4, 14),
        density=st.sampled_from([0.0, 0.15, 0.6]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_dense_conv(self, h, w, density, seed):
        key = jax.random.PRNGKey(seed)
        fmap = (jax.random.uniform(key, (h, w)) < density).astype(jnp.float32)
        wk = jax.random.normal(jax.random.PRNGKey(seed + 1), (3, 3))
        ev = events_from_fmap(fmap, h * w)
        vm0 = jax.random.normal(jax.random.PRNGKey(seed + 2), (h - 2, w - 2))
        got = event_conv_scatter(ev, wk, vm0)
        want = vm0 + ref.valid_conv3(fmap[..., None], wk[..., None, None])[..., 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_event_count_scales_work(self):
        # padded events are ignored: result independent of padding length
        fmap = jnp.zeros((6, 6)).at[2, 3].set(1.0)
        wk = jnp.arange(9.0).reshape(3, 3)
        vm0 = jnp.zeros((4, 4))
        a = event_conv_scatter(events_from_fmap(fmap, 4), wk, vm0)
        b = event_conv_scatter(events_from_fmap(fmap, 36), wk, vm0)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_corner_event_obb_masked(self):
        # event at (0,0): only output (0,0) is in bounds
        fmap = jnp.zeros((5, 5)).at[0, 0].set(1.0)
        wk = jnp.arange(1.0, 10.0).reshape(3, 3)
        out = event_conv_scatter(events_from_fmap(fmap, 25), wk, jnp.zeros((3, 3)))
        out = np.asarray(out)
        assert out[0, 0] == 1.0  # w[0,0] (k = p - o = 0)
        assert np.count_nonzero(out) == 1


class TestRefPrimitives:
    def test_or_maxpool(self):
        x = jnp.zeros((6, 6, 2)).at[0, 0, 0].set(1.0).at[5, 5, 1].set(1.0)
        p = ref.or_maxpool3(x)
        assert p.shape == (2, 2, 2)
        assert float(p[0, 0, 0]) == 1.0
        assert float(p[1, 1, 1]) == 1.0
        assert float(jnp.sum(p)) == 2.0

    @given(seed=st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_encode_mttfs_monotone(self, seed):
        key = jax.random.PRNGKey(seed)
        img = jax.random.uniform(key, (28, 28))
        th = jnp.asarray([0.15, 0.3, 0.45, 0.6, 0.75])
        frames = np.asarray(ref.encode_mttfs(img, th))
        # spikes only ever get added over timesteps (m-TTFS)
        for t in range(1, 5):
            assert np.all(frames[t] >= frames[t - 1])

    def test_fc_accumulate(self):
        spikes = jnp.zeros((2, 2, 3)).at[1, 0, 2].set(1.0)
        w = jnp.arange(12.0 * 10).reshape(12, 10)
        b = jnp.ones((10,))
        acc = ref.fc_accumulate(jnp.zeros(10), spikes, w, b)
        flat_idx = (1 * 2 + 0) * 3 + 2
        np.testing.assert_allclose(np.asarray(acc), np.asarray(w[flat_idx] + 1.0))
