"""Training + ANN->SNN conversion + quantization (build-time only).

Pipeline (paper §VII):
  1. train a clamped-ReLU CNN (Tensorflow-Keras in the paper; JAX here)
     on the (synthetic) dataset,
  2. convert to an m-TTFS IF-SNN with Rueckauer-style data-based
     threshold balancing (the SNN-Toolbox step in the paper),
  3. post-training quantization to 8/16-bit integer weights with
     per-layer scales and a saturating accumulator (the paper's
     quantization-aware step is approximated by quantize + finetune-free
     calibration, which suffices at these model sizes),
  4. export everything as tensor archives for the Rust side.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import ref

# m-TTFS input binarization thresholds (strictly increasing, paper §VII).
INPUT_THRESHOLDS = (0.15, 0.30, 0.45, 0.60, 0.75)


def mttfs_quantize(imgs: np.ndarray) -> np.ndarray:
    """Project [0,1] frames onto the m-TTFS input domain.

    The SNN's effective input drive over T steps is the *count* of
    thresholds each pixel exceeds (each binarized frame is integrated
    once), so the ANN is trained on exactly that quantized intensity —
    this keeps the ANN->SNN conversion input-consistent (paper §VII's
    "set of thresholds" binarization).
    """
    th = np.asarray(INPUT_THRESHOLDS, np.float32)
    counts = (imgs[..., None] > th).sum(-1).astype(np.float32)
    return counts / len(INPUT_THRESHOLDS)


# ---------------------------------------------------------------------------
# ANN training (clamped ReLU CNN, Adam, softmax cross-entropy)
# ---------------------------------------------------------------------------

def init_weights(seed: int):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)

    def conv_init(key, cin, cout):
        std = float(np.sqrt(2.0 / (9 * cin)))
        return (jax.random.normal(key, (3, 3, cin, cout)) * std,
                jnp.zeros((cout,)))

    w1 = conv_init(k1, 1, 32)
    w2 = conv_init(k2, 32, 32)
    w3 = conv_init(k3, 32, 10)
    wf = (jax.random.normal(k4, (M.SHAPES["fc_in"], 10))
          * float(np.sqrt(2.0 / M.SHAPES["fc_in"])), jnp.zeros((10,)))
    return [w1, w2, w3, wf]


def _loss(weights, imgs, labels):
    logits, _ = jax.vmap(lambda im: M.ann_forward(weights, im))(imgs)
    logits = logits[0] if isinstance(logits, tuple) else logits
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


@functools.partial(jax.jit, static_argnames=("lr",))
def _adam_step(weights, mstate, vstate, t, imgs, labels, lr=1e-3):
    grads = jax.grad(_loss)(weights, imgs, labels)
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_w, new_m, new_v = [], [], []
    for w, m, v, g in zip(weights, mstate, vstate, grads):
        layer_w, layer_m, layer_v = [], [], []
        for wi, mi, vi, gi in zip(w, m, v, g):
            mi = b1 * mi + (1 - b1) * gi
            vi = b2 * vi + (1 - b2) * gi * gi
            mhat = mi / (1 - b1 ** t)
            vhat = vi / (1 - b2 ** t)
            layer_w.append(wi - lr * mhat / (jnp.sqrt(vhat) + eps))
            layer_m.append(mi)
            layer_v.append(vi)
        new_w.append(tuple(layer_w))
        new_m.append(tuple(layer_m))
        new_v.append(tuple(layer_v))
    return new_w, new_m, new_v


def train_cnn(xs: np.ndarray, ys: np.ndarray, *, epochs: int = 4,
              batch: int = 64, seed: int = 0, lr: float = 1e-3,
              verbose: bool = True):
    """xs: (N, 28, 28) u8, ys: (N,) u8. Returns float weights list."""
    weights = init_weights(seed)
    mstate = [tuple(jnp.zeros_like(p) for p in w) for w in weights]
    vstate = [tuple(jnp.zeros_like(p) for p in w) for w in weights]
    imgs = mttfs_quantize(xs.astype(np.float32) / 255.0)[..., None]
    labels = ys.astype(np.int32)
    rng = np.random.default_rng(seed)
    n = len(xs)
    t = 0
    for ep in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            t += 1
            weights, mstate, vstate = _adam_step(
                weights, mstate, vstate, t,
                jnp.asarray(imgs[idx]), jnp.asarray(labels[idx]), lr=lr)
        if verbose:
            acc = evaluate_ann(weights, xs[:512], ys[:512])
            print(f"  epoch {ep + 1}/{epochs}: train-acc(512)={acc:.3f}")
    return weights


def evaluate_ann(weights, xs, ys, batch: int = 256) -> float:
    imgs = mttfs_quantize(xs.astype(np.float32) / 255.0)[..., None]
    correct = 0
    fwd = jax.jit(jax.vmap(lambda im: M.ann_forward(weights, im)[0]))
    for i in range(0, len(xs), batch):
        logits = fwd(jnp.asarray(imgs[i : i + batch]))
        correct += int((np.argmax(np.asarray(logits), -1) == ys[i : i + batch]).sum())
    return correct / len(xs)


# ---------------------------------------------------------------------------
# ANN -> SNN conversion (data-based threshold balancing, Rueckauer et al.)
# ---------------------------------------------------------------------------

def convert_to_snn(weights, xs_calib: np.ndarray, *, percentile: float = 99.9,
                   thresholds=INPUT_THRESHOLDS) -> M.CsnnParams:
    """Normalize per-layer so that V_t = 1.0 everywhere.

    lambda_l = percentile of layer-l activations over the calibration set;
    w_l <- w_l * lambda_{l-1} / lambda_l, b_l <- b_l / lambda_l.
    """
    imgs = mttfs_quantize(xs_calib.astype(np.float32) / 255.0)[..., None]
    fwd = jax.jit(jax.vmap(lambda im: M.ann_forward(weights, im)[1]))
    acts = fwd(jnp.asarray(imgs))
    a1, a2, _a2p, a3 = (np.asarray(a) for a in acts)
    lams = [1.0]
    for a in (a1, a2, a3):
        lam = float(np.percentile(a, percentile))
        lams.append(max(lam, 1e-3))

    conv = []
    for li, (w, b) in enumerate(weights[:3]):
        lam_prev, lam = lams[li], lams[li + 1]
        conv.append(M.ConvLayer(
            w=jnp.asarray(np.asarray(w) * lam_prev / lam),
            b=jnp.asarray(np.asarray(b) / lam),
            vt=1.0,
        ))
    wf, bf = weights[3]
    fc = M.FcLayer(w=jnp.asarray(np.asarray(wf) * lams[3]),
                   b=jnp.asarray(np.asarray(bf)))
    params = M.CsnnParams(
        conv=tuple(conv), fc=fc,
        thresholds=jnp.asarray(thresholds, jnp.float32),
        sat_min=-float("inf"), sat_max=float("inf"),
    )
    return params


def calibrate_vt(params: M.CsnnParams, xs: np.ndarray, ys: np.ndarray,
                 factors=(0.4, 0.55, 0.7, 0.85, 1.0)) -> M.CsnnParams:
    """Global V_t balancing: the m-TTFS spike-count nonlinearity shifts the
    best operating point below the rate-coding V_t = 1.0; sweep a small set
    of global multipliers on a calibration split and keep the best (the
    empirical step SNN-Toolbox performs as 'threshold balancing')."""
    best, best_acc = params, -1.0
    for f in factors:
        cand = params._replace(conv=tuple(
            layer._replace(vt=layer.vt * f) for layer in params.conv))
        acc = evaluate_snn(cand, xs, ys)
        if acc > best_acc:
            best, best_acc = cand, acc
    return best


# ---------------------------------------------------------------------------
# Quantization to integer weights with saturating accumulators
# ---------------------------------------------------------------------------

class QuantInfo(NamedTuple):
    bits: int
    acc_bits: int
    scales: list      # per conv layer weight scale
    fc_scale: float
    vt_q: list        # per conv layer integer threshold


def quantize_snn(params: M.CsnnParams, bits: int) -> tuple[M.CsnnParams, QuantInfo]:
    """Symmetric per-layer quantization; membrane unit = 1/S_l.

    Returns a params pytree whose values are INTEGRAL float32 (so the same
    JAX model runs them exactly — f32 holds integers < 2^24 exactly) plus
    the scales needed by the Rust integer datapath.
    """
    qmax = 2 ** (bits - 1) - 1
    # Accumulator width: wide enough that saturation never engages for the
    # paper network's actual dynamic range (so the f32 golden model and the
    # integer simulator agree bit-exactly; see DESIGN.md). The saturating
    # datapath itself is still implemented and unit-tested at narrow widths
    # in rust/src/snn/sat.rs.
    acc_bits = 20 if bits == 8 else 24
    sat_max = float(2 ** (acc_bits - 1) - 1)

    conv_q, scales, vts = [], [], []
    for layer in params.conv:
        wmax = float(max(np.abs(np.asarray(layer.w)).max(),
                         np.abs(np.asarray(layer.b)).max(), 1e-6))
        s = qmax / wmax
        wq = np.round(np.asarray(layer.w) * s)
        bq = np.round(np.asarray(layer.b) * s)
        vt_q = float(np.round(layer.vt * s))
        conv_q.append(M.ConvLayer(w=jnp.asarray(wq), b=jnp.asarray(bq), vt=vt_q))
        scales.append(s)
        vts.append(vt_q)

    wf = np.asarray(params.fc.w)
    sf = qmax / float(max(np.abs(wf).max(), 1e-6))
    fc_q = M.FcLayer(w=jnp.asarray(np.round(wf * sf)),
                     b=jnp.asarray(np.round(np.asarray(params.fc.b) * sf)))

    qparams = M.CsnnParams(
        conv=tuple(conv_q), fc=fc_q, thresholds=params.thresholds,
        sat_min=-sat_max, sat_max=sat_max,
    )
    return qparams, QuantInfo(bits, acc_bits, scales, sf, vts)


# ---------------------------------------------------------------------------
# SNN evaluation
# ---------------------------------------------------------------------------

def evaluate_snn(params: M.CsnnParams, xs: np.ndarray, ys: np.ndarray,
                 batch: int = 128) -> float:
    imgs = xs.astype(np.float32) / 255.0

    @jax.jit
    def fwd(ims):
        def one(im):
            frames = ref.encode_mttfs(im, params.thresholds)
            logits, _ = M.csnn_forward(params, frames)
            return jnp.argmax(logits)
        return jax.vmap(one)(ims)

    correct = 0
    for i in range(0, len(xs), batch):
        pred = np.asarray(fwd(jnp.asarray(imgs[i : i + batch])))
        correct += int((pred == ys[i : i + batch]).sum())
    return correct / len(xs)
