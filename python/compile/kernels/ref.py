"""Pure-jnp reference oracle for the CSNN layer computations.

This module is the *semantics definition* of the reproduction. Everything
else — the Pallas kernels (L1), the full JAX model (L2) and, transitively,
the Rust cycle-level simulator (L3, checked against the AOT-lowered golden
model) — is validated against the functions in this file.

Conventions (normative, see DESIGN.md §6):
  * fmaps are (H, W, C) float32 with binary values {0.0, 1.0},
  * convolutions are VALID 3x3 (28 -> 26 -> 24 -> pool3 -> 8 -> 6),
  * m-TTFS: a neuron that has crossed V_t keeps firing every subsequent
    timestep until the sample is reset (spike-indicator bit `fired`),
  * the bias is added to every membrane potential once per timestep by the
    thresholding unit (not per input spike),
  * all arithmetic saturates to the accumulator range [sat_min, sat_max]
    (the hardware's saturation arithmetic). For the float model the range
    is +/- inf; for the quantized model it is the Q-format range.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "valid_conv3",
    "saturate",
    "if_layer_step",
    "or_maxpool3",
    "encode_mttfs",
    "fc_accumulate",
]


def valid_conv3(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """VALID 3x3 convolution. x: (H, W, Cin), w: (3, 3, Cin, Cout).

    Returns (H-2, W-2, Cout). This is the frame-based formulation; the
    hardware performs the event-based equivalent (scatter the 180-degree
    rotated kernel at each address event), which produces identical results
    — a property the Rust test-suite checks exhaustively.
    """
    lhs = x[None, ...]  # NHWC
    out = jax.lax.conv_general_dilated(
        lhs,
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0]


def saturate(v: jnp.ndarray, sat_min: float, sat_max: float) -> jnp.ndarray:
    """Saturation arithmetic: clamp to the representable accumulator range."""
    return jnp.clip(v, sat_min, sat_max)


def if_layer_step(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    vm: jnp.ndarray,
    fired: jnp.ndarray,
    vt: float,
    sat_min: float = -jnp.inf,
    sat_max: float = jnp.inf,
):
    """One timestep of an m-TTFS IF convolutional layer.

    Mirrors the hardware schedule of one (layer, c_out, t) unit of work:
      1. convolution unit: vm += conv(x)         (event-based in HW)
      2. thresholding unit: vm += b; spike if vm > vt or already fired.

    Args:
      x:      (H, W, Cin) binary input spikes at timestep t.
      w:      (3, 3, Cin, Cout) kernel.
      b:      (Cout,) bias, added once per timestep.
      vm:     (H-2, W-2, Cout) membrane potentials (state).
      fired:  (H-2, W-2, Cout) bool spike-indicator bits (state).
      vt:     firing threshold.

    Returns (spikes, vm', fired') with spikes binary float32.
    """
    u = valid_conv3(x, w)
    vm = saturate(vm + u, sat_min, sat_max)
    vm = saturate(vm + b[None, None, :], sat_min, sat_max)
    fired = jnp.logical_or(fired, vm > vt)
    spikes = fired.astype(jnp.float32)
    return spikes, vm, fired


def or_maxpool3(x: jnp.ndarray) -> jnp.ndarray:
    """3x3/stride-3 max-pool on a binary fmap == OR over each window.

    x: (H, W, C) with H, W divisible by 3 -> (H/3, W/3, C).
    """
    h, w, c = x.shape
    assert h % 3 == 0 and w % 3 == 0, f"pool dims must divide 3, got {x.shape}"
    xr = x.reshape(h // 3, 3, w // 3, 3, c)
    return jnp.max(xr, axis=(1, 3))


def encode_mttfs(img: jnp.ndarray, thresholds: jnp.ndarray) -> jnp.ndarray:
    """Binarize an input frame into T timesteps of m-TTFS spikes.

    `thresholds` is the paper's strictly increasing set P = (p_1..p_T)
    (one per timestep here). It is applied in DECREASING order over time so
    that a bright pixel spikes early *and keeps spiking* (the m-TTFS
    property): step 0 uses the largest threshold.

    img: (H, W) float in [0, 1].  Returns (T, H, W, 1) binary float32.
    """
    desc = thresholds[::-1]  # largest first
    spikes = (img[None, :, :] > desc[:, None, None]).astype(jnp.float32)
    return spikes[..., None]


def fc_accumulate(acc: jnp.ndarray, spikes: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Classification unit: accumulate FC potentials from binary spikes.

    acc: (n_out,), spikes: (H, W, C) binary, w: (H*W*C, n_out), b: (n_out,).
    The hardware implements this as event-driven adds of weight rows.
    """
    flat = spikes.reshape(-1)
    return acc + flat @ w + b
