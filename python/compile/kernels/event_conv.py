"""L1 Pallas kernel: event-driven convolution (the AEQ processing model).

This is the *direct software analogue* of the paper's convolution unit:
spikes arrive as a queue of address events, and each event scatters the
180-degree-rotated 3x3 kernel into the membrane-potential neighbourhood
(Morales et al. / paper §V-B). The number of inner-loop iterations scales
with the number of events, exactly like the hardware's one-cycle-per-event
schedule — this kernel is what the Rust cycle-level simulator's datapath
computes, expressed in Pallas.

VALID-convolution geometry (DESIGN.md §6): an input event at position
p = (px, py) updates the output positions o in [p-2, p] x [p-2, p]
(clipped to the output fmap) with weight w[p - o]; over the 3x3 window
that is the kernel rotated by 180 degrees.

Implementation notes:
  * The membrane fmap is padded by 2 on every side so the scatter window
    never leaves the buffer; out-of-fmap contributions land in the pad
    margin and are cropped by the wrapper — the software analogue of the
    hardware's under/overflow-based out-of-bounds detection.
  * Events are passed as an (N, 2) int32 array padded with (-1, -1);
    invalid rows contribute zero (the AEQ's `valid` bit).
  * `interpret=True` only; see csnn_step.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["event_conv_scatter", "events_from_fmap"]


def events_from_fmap(fmap, max_events: int):
    """Compress a binary (H, W) fmap into an (N, 2) address-event queue.

    Row-major scan order (the order the hardware's thresholding unit emits
    events). Pads with (-1, -1) up to `max_events`. Pure-jnp utility used
    by tests and by the AOT pipeline to build event traces for Rust.
    """
    h, w = fmap.shape
    ys, xs = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    flat = fmap.reshape(-1) > 0
    order = jnp.argsort(~flat, stable=True)  # spikes first, stable scan order
    ev = jnp.stack([ys.reshape(-1)[order], xs.reshape(-1)[order]], axis=-1)
    valid = flat[order][:, None]
    ev = jnp.where(valid, ev, -1)
    return ev[:max_events].astype(jnp.int32)


def _kernel(ev_ref, w_ref, vm_ref, vm_out, *, n_events: int):
    """Sequential event scatter — one 3x3 saturating update per event."""
    w_rot = w_ref[...][::-1, ::-1]  # 180-degree rotation (paper Fig. 4)
    vm_out[...] = vm_ref[...]

    def body(k, _):
        px = pl.load(ev_ref, (k, 0))
        py = pl.load(ev_ref, (k, 1))
        valid = (px >= 0).astype(jnp.float32)
        # Window top-left in padded coordinates: (px - 2) + pad(2) = px.
        sx = jnp.maximum(px, 0)  # keep indices non-negative for invalid rows
        sy = jnp.maximum(py, 0)
        idx = (pl.dslice(sx, 3), pl.dslice(sy, 3))
        cur = pl.load(vm_out, idx)
        pl.store(vm_out, idx, cur + w_rot * valid)
        return 0

    jax.lax.fori_loop(0, n_events, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def event_conv_scatter(events, w, vm, *, interpret: bool = True):
    """Event-driven VALID 3x3 convolution accumulation.

    Args:
      events: (N, 2) int32 address events (row, col) in INPUT coordinates,
              padded with (-1, -1).
      w:      (3, 3) float32 kernel (un-rotated; rotation happens inside).
      vm:     (Ho, Wo) float32 membrane potentials to accumulate into.

    Returns vm' = vm + event_conv(events, w), identical to
    `ref.valid_conv3` of the event image — the property pytest asserts.
    """
    ho, wo = vm.shape
    n = events.shape[0]
    # Pad by 2: the window start in padded coords is (px - 2) + 2 = px, with
    # px in [0, Ho+1]; the window end px+2 <= Ho+3 stays inside (Ho+4).
    vm_pad = jnp.pad(vm, 2)
    out = pl.pallas_call(
        functools.partial(_kernel, n_events=n),
        out_shape=jax.ShapeDtypeStruct(vm_pad.shape, jnp.float32),
        interpret=interpret,
    )(events, w, vm_pad)
    return out[2 : 2 + ho, 2 : 2 + wo]
