"""L1 Pallas kernel: fused m-TTFS IF-convolution layer timestep.

Hardware adaptation (DESIGN.md §2): the paper's FPGA exploits sparsity with
9 adder-PEs fed from compressed address-event queues. On TPU the same
insight — "do work proportional to spikes, keep the compute unit
saturated" — maps differently: the MXU is a 128x128 systolic array that
wants dense bf16/f32 matmuls, and the scarce resource is VMEM residency,
not adders. We therefore:

  * reformulate the binary-fmap VALID 3x3 convolution as an im2col patch
    matrix (Ho*Wo, 9*Cin) x weight matrix (9*Cin, Cout) product (MXU
    friendly, no gather in the inner loop),
  * block the grid over OUTPUT CHANNELS, the direct analogue of the
    paper's channel-multiplexed MemPot reuse (Algorithm 1): each grid step
    owns one (Ho, Wo, Cb) membrane tile resident in VMEM,
  * fuse membrane integration, per-timestep bias, saturation arithmetic
    and the m-TTFS threshold (spike-indicator OR) into the same kernel so
    the membrane tile makes exactly one HBM round-trip per timestep,
  * exploit sparsity at tile granularity: a whole-tile population count
    predicates the matmul (`pl.when`), the TPU analogue of "empty queue
    columns cost one cycle, not a full pass".

The kernel is lowered with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); correctness is asserted against
``ref.if_layer_step`` and TPU performance is *estimated* from the VMEM
footprint + MXU utilization in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["if_layer_step_pallas", "weights_to_matrix", "im2col_valid3"]


def weights_to_matrix(w: jnp.ndarray) -> jnp.ndarray:
    """(3, 3, Cin, Cout) -> (9*Cin, Cout) in (dy, dx, cin) row-major order.

    Row index f = (3*dy + dx) * Cin + cin — must match `im2col_valid3`.
    """
    k0, k1, cin, cout = w.shape
    assert k0 == 3 and k1 == 3, f"3x3 kernels only, got {w.shape}"
    return w.reshape(9 * cin, cout)


def im2col_valid3(x: jnp.ndarray) -> jnp.ndarray:
    """(H, W, Cin) -> (Ho*Wo, 9*Cin) patch matrix for a VALID 3x3 conv."""
    h, w, cin = x.shape
    ho, wo = h - 2, w - 2
    cols = [x[dy : dy + ho, dx : dx + wo, :] for dy in range(3) for dx in range(3)]
    patches = jnp.concatenate(cols, axis=-1)  # (Ho, Wo, 9*Cin)
    return patches.reshape(ho * wo, 9 * cin)


def _kernel(x_ref, wm_ref, b_ref, vm_ref, fired_ref,
            spikes_out, vm_out, fired_out,
            *, vt: float, sat_min: float, sat_max: float, ho: int, wo: int):
    """One output-channel block: conv + integrate + bias + m-TTFS threshold."""
    x = x_ref[...]                      # (H, W, Cin) binary
    wm = wm_ref[...]                    # (9*Cin, Cb)
    b = b_ref[...]                      # (Cb,)
    vm = vm_ref[...]                    # (Ho, Wo, Cb)
    fired = fired_ref[...]              # (Ho, Wo, Cb) in {0, 1}

    cb = wm.shape[1]

    def compute_update(_):
        patches = im2col_valid3(x)      # (Ho*Wo, 9*Cin)
        u = jnp.dot(patches, wm, preferred_element_type=jnp.float32)
        return u.reshape(ho, wo, cb)

    # Tile-level sparsity predicate: with zero input spikes the convolution
    # contributes nothing — skip the MXU dispatch entirely (the paper's
    # "processing time scales with the number of spikes", at tile grain).
    n_spikes = jnp.sum(x)
    u = jax.lax.cond(n_spikes > 0, compute_update,
                     lambda _: jnp.zeros((ho, wo, cb), jnp.float32), None)

    vm = jnp.clip(vm + u, sat_min, sat_max)
    vm = jnp.clip(vm + b[None, None, :], sat_min, sat_max)
    fired = jnp.maximum(fired, (vm > vt).astype(jnp.float32))

    spikes_out[...] = fired
    vm_out[...] = vm
    fired_out[...] = fired


@functools.partial(
    jax.jit,
    static_argnames=("vt", "sat_min", "sat_max", "block_cout", "interpret"),
)
def if_layer_step_pallas(
    x: jnp.ndarray,
    wm: jnp.ndarray,
    b: jnp.ndarray,
    vm: jnp.ndarray,
    fired: jnp.ndarray,
    *,
    vt: float,
    sat_min: float = -3.0e38,
    sat_max: float = 3.0e38,
    block_cout: int = 8,
    interpret: bool = True,
):
    """Fused IF layer timestep. See module docstring.

    Args:
      x:     (H, W, Cin) binary float32 input spikes.
      wm:    (9*Cin, Cout) weight matrix (see `weights_to_matrix`).
      b:     (Cout,) bias (added once per timestep).
      vm:    (Ho, Wo, Cout) membrane potentials.
      fired: (Ho, Wo, Cout) spike indicators as float {0, 1}.

    Returns (spikes, vm', fired'), all (Ho, Wo, Cout) float32.

    VMEM accounting per grid step (f32): x (H*W*Cin) + wm (9*Cin*Cb) +
    3x membrane tile (Ho*Wo*Cb). For the paper's largest layer
    (26x26x32 -> 24x24x32, Cb=8): 26*26*32 + 9*32*8 + 3*24*24*8 = 100 KiB
    — comfortably inside a 16 MiB VMEM budget, leaving room to scale Cb
    and double-buffer the HBM->VMEM stream.
    """
    h, w, cin = x.shape
    ho, wo = h - 2, w - 2
    cout = wm.shape[1]
    cb = min(block_cout, cout)
    assert cout % cb == 0, f"block_cout {cb} must divide Cout {cout}"
    grid = (cout // cb,)

    out_shape = [
        jax.ShapeDtypeStruct((ho, wo, cout), jnp.float32),
        jax.ShapeDtypeStruct((ho, wo, cout), jnp.float32),
        jax.ShapeDtypeStruct((ho, wo, cout), jnp.float32),
    ]
    mem_spec = pl.BlockSpec((ho, wo, cb), lambda c: (0, 0, c))
    kernel = functools.partial(
        _kernel, vt=vt, sat_min=sat_min, sat_max=sat_max, ho=ho, wo=wo
    )
    spikes, vm2, fired2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, w, cin), lambda c: (0, 0, 0)),      # x: replicated
            pl.BlockSpec((9 * cin, cb), lambda c: (0, c)),        # wm: channel block
            pl.BlockSpec((cb,), lambda c: (c,)),                  # b
            mem_spec,                                             # vm
            mem_spec,                                             # fired
        ],
        out_specs=[mem_spec, mem_spec, mem_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, wm, b, vm, fired)
    return spikes, vm2, fired2
