"""Tensor-archive writer: the Python->Rust weight/dataset interchange.

No serde is available on the Rust side offline, so the interchange is a
tiny self-describing little-endian binary format (reader:
`rust/src/artifact/archive.rs`):

    u32  magic   = 0x53414354  ("SACT")
    u32  version = 1
    u32  n_tensors
    per tensor:
        u32  name_len, name_len bytes of UTF-8
        u8   dtype  (0=f32, 1=i32, 2=i16, 3=i8, 4=u8)
        u32  ndim
        u32  dims[ndim]
        u64  byte_len
        raw little-endian data
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = 0x53414354
VERSION = 1

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.int16): 2,
    np.dtype(np.int8): 3,
    np.dtype(np.uint8): 4,
}


def write_archive(path: str, tensors: dict) -> None:
    """tensors: name -> np.ndarray (f32/i32/i16/i8/u8)."""
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC, VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BI", _DTYPES[arr.dtype], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_archive(path: str) -> dict:
    """Round-trip reader (used by pytest to validate the writer)."""
    inv = {v: k for k, v in _DTYPES.items()}
    out = {}
    with open(path, "rb") as f:
        magic, version, count = struct.unpack("<III", f.read(12))
        assert magic == MAGIC and version == VERSION
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BI", f.read(5))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            (blen,) = struct.unpack("<Q", f.read(8))
            arr = np.frombuffer(f.read(blen), dtype=inv[dt]).reshape(dims)
            out[name] = arr
    return out
