"""L2: the full CSNN as a JAX computation (build-time only).

Network (paper §VII, valid-conv interpretation, DESIGN.md §6):

    28x28x1 -> 32C3 -> 26x26x32 -> 32C3 -> 24x24x32 -> P3 -> 8x8x32
            -> 10C3 -> 6x6x10 -> F10

m-TTFS over T=5 timesteps; biases applied once per timestep by the
thresholding unit; OR max-pool; classification by accumulated FC
potentials. The same function doubles as

  * the float golden model (sat bounds = +/-inf) used to score accuracy,
  * the QUANTIZED golden model (integral weights, finite saturation) that
    is AOT-lowered to HLO text and executed from Rust via PJRT — the
    cycle-level simulator must match it spike-for-spike.

`use_pallas=True` routes every layer step through the L1 Pallas kernel
(interpret mode) so the exported HLO exercises the kernel path.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.csnn_step import if_layer_step_pallas, weights_to_matrix


class ConvLayer(NamedTuple):
    w: jnp.ndarray   # (3, 3, Cin, Cout)
    b: jnp.ndarray   # (Cout,)
    vt: float


class FcLayer(NamedTuple):
    w: jnp.ndarray   # (n_in, n_out)
    b: jnp.ndarray   # (n_out,)


class CsnnParams(NamedTuple):
    conv: Sequence[ConvLayer]     # exactly 3 layers for the paper net
    fc: FcLayer
    thresholds: jnp.ndarray       # (T,) strictly increasing input thresholds
    sat_min: float
    sat_max: float


# Layer geometry of the paper network (input 28x28).
SHAPES = {
    "input": (28, 28, 1),
    "l1": (26, 26, 32),
    "l2": (24, 24, 32),
    "l2_pool": (8, 8, 32),
    "l3": (6, 6, 10),
    "fc_in": 6 * 6 * 10,
    "n_classes": 10,
}
T_STEPS = 5


def init_state(params: CsnnParams):
    """Zeroed membrane potentials, spike indicators and FC accumulator."""
    vms, fireds = [], []
    for name in ("l1", "l2", "l3"):
        h, w, c = SHAPES[name]
        vms.append(jnp.zeros((h, w, c), jnp.float32))
        fireds.append(jnp.zeros((h, w, c), jnp.float32))
    acc = jnp.zeros((SHAPES["n_classes"],), jnp.float32)
    return tuple(vms), tuple(fireds), acc


def _layer_step(x, layer: ConvLayer, vm, fired, sat_min, sat_max, use_pallas):
    if use_pallas:
        cout = layer.w.shape[-1]
        block = 2 if cout % 8 else 8  # 10-channel layer blocks by 2
        return if_layer_step_pallas(
            x, weights_to_matrix(layer.w), layer.b, vm, fired,
            vt=float(layer.vt), sat_min=float(sat_min), sat_max=float(sat_max),
            block_cout=block,
        )
    s, vm2, f2 = ref.if_layer_step(
        x, layer.w, layer.b, vm, fired > 0.5, float(layer.vt),
        sat_min=sat_min, sat_max=sat_max,
    )
    return s, vm2, f2.astype(jnp.float32)


def csnn_step(params: CsnnParams, state, frame, use_pallas: bool = False):
    """One network timestep: all three conv layers + pooling + FC unit.

    frame: (28, 28, 1) binary spikes. Returns (state', per-layer spikes).
    """
    (vm1, vm2, vm3), (f1, f2, f3), acc = state
    l1, l2, l3 = params.conv
    sat = (params.sat_min, params.sat_max)

    s1, vm1, f1 = _layer_step(frame, l1, vm1, f1, *sat, use_pallas)
    s2, vm2, f2 = _layer_step(s1, l2, vm2, f2, *sat, use_pallas)
    s2p = ref.or_maxpool3(s2)
    s3, vm3, f3 = _layer_step(s2p, l3, vm3, f3, *sat, use_pallas)
    acc = ref.fc_accumulate(acc, s3, params.fc.w, params.fc.b)

    state = ((vm1, vm2, vm3), (f1, f2, f3), acc)
    return state, (s1, s2p, s3)


def csnn_forward(params: CsnnParams, frames, use_pallas: bool = False):
    """Run T timesteps. frames: (T, 28, 28, 1) binary.

    Returns (logits (10,), spike_counts (T, 3)) — the per-layer, per-step
    spike counts are the cross-check signal for the Rust simulator.
    """
    state = init_state(params)

    def step(state, frame):
        state, (s1, s2p, s3) = csnn_step(params, state, frame, use_pallas)
        counts = jnp.stack([jnp.sum(s1), jnp.sum(s2p), jnp.sum(s3)])
        return state, counts

    if use_pallas:
        # pallas_call inside scan is fine, but unrolling keeps the lowered
        # HLO free of while-loops, which the PJRT-side profiler likes.
        counts = []
        for t in range(frames.shape[0]):
            state, c = step(state, frames[t])
            counts.append(c)
        spike_counts = jnp.stack(counts)
    else:
        state, spike_counts = jax.lax.scan(step, state, frames)

    _, _, acc = state
    return acc, spike_counts


def classify(params: CsnnParams, img) -> jnp.ndarray:
    """End-to-end: encode a (28, 28) [0,1] frame, run T steps, argmax."""
    frames = ref.encode_mttfs(img, params.thresholds)
    logits, _ = csnn_forward(params, frames)
    return jnp.argmax(logits)


# ---------------------------------------------------------------------------
# The ANN used for training (clamped ReLU, conversion source — paper §VII).
# ---------------------------------------------------------------------------

def ann_forward(weights, img):
    """Clamped-ReLU CNN with the same topology; img: (28, 28, 1) in [0,1]."""
    (w1, b1), (w2, b2), (w3, b3), (wf, bf) = weights

    def clamped_relu(v):
        return jnp.clip(v, 0.0, 1.0)

    a1 = clamped_relu(ref.valid_conv3(img, w1) + b1)
    a2 = clamped_relu(ref.valid_conv3(a1, w2) + b2)
    a2p = jax.lax.reduce_window(a2, -jnp.inf, jax.lax.max, (3, 3, 1), (3, 3, 1), "VALID")
    a3 = clamped_relu(ref.valid_conv3(a2p, w3) + b3)
    logits = a3.reshape(-1) @ wf + bf
    return logits, (a1, a2, a2p, a3)
