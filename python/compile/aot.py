"""AOT entry point: `python -m compile.aot --out ../artifacts`.

Runs ONCE at `make artifacts` and produces everything the Rust binary
needs at runtime (python never appears on the request path):

  model_q8.hlo.txt / model_q16.hlo.txt
      quantized golden CSNN, frames (T,28,28,1) -> (logits, spike_counts);
      lowered THROUGH the L1 Pallas kernel path so the exported HLO is the
      kernel's lowering. Interchange is HLO *text* (xla_extension 0.5.1
      rejects jax>=0.5 serialized protos — see /opt/xla-example/README.md).
  layer_step.hlo.txt
      single L1-geometry layer step with explicit (x, wm, b, vm, fired)
      args for fine-grained Rust<->JAX cross-checking on random inputs.
  weights_f32.bin, weights_q8.bin, weights_q16.bin
      tensor archives (see archive.py) with the converted SNN parameters.
  mnist.bin, fashion.bin
      synthetic datasets (train/test x/y).
  meta.json
      geometry, thresholds, quantization scales, saturation bounds and the
      build-time accuracy measurements.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import archive, data, train
from . import model as M

N_TRAIN = 3000
N_TEST = 1000
N_EVAL = 500          # images scored at build time (kept small for speed)
SEED = 7


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large constants as `{...}`,
    # which the HLO text parser silently reads back as ZEROS — the model
    # weights are baked-in constants, so they must be printed in full.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8 emits `source_end_line` etc. metadata that xla_extension
    # 0.5.1's parser rejects; strip metadata from the interchange text.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_model(params: M.CsnnParams, use_pallas: bool) -> str:
    def fwd(frames):
        logits, counts = M.csnn_forward(params, frames, use_pallas=use_pallas)
        return logits, counts

    spec = jax.ShapeDtypeStruct((M.T_STEPS, 28, 28, 1), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_layer_step(vt: float, sat: float) -> str:
    from .kernels.csnn_step import if_layer_step_pallas

    def step(x, wm, b, vm, fired):
        return if_layer_step_pallas(
            x, wm, b, vm, fired, vt=vt, sat_min=-sat, sat_max=sat, block_cout=8
        )

    specs = [
        jax.ShapeDtypeStruct((28, 28, 1), jnp.float32),    # x
        jax.ShapeDtypeStruct((9, 32), jnp.float32),        # wm
        jax.ShapeDtypeStruct((32,), jnp.float32),          # b
        jax.ShapeDtypeStruct((26, 26, 32), jnp.float32),   # vm
        jax.ShapeDtypeStruct((26, 26, 32), jnp.float32),   # fired
    ]
    return to_hlo_text(jax.jit(step).lower(*specs))


def export_params(path: str, params: M.CsnnParams, as_int: bool):
    t = {}
    for i, layer in enumerate(params.conv):
        dt = np.int32 if as_int else np.float32
        t[f"conv{i}_w"] = np.asarray(layer.w).astype(dt)
        t[f"conv{i}_b"] = np.asarray(layer.b).astype(dt)
        t[f"conv{i}_vt"] = np.asarray([layer.vt]).astype(dt)
    t["fc_w"] = np.asarray(params.fc.w).astype(np.int32 if as_int else np.float32)
    t["fc_b"] = np.asarray(params.fc.b).astype(np.int32 if as_int else np.float32)
    t["thresholds"] = np.asarray(params.thresholds, np.float32)
    archive.write_archive(path, t)


def export_dataset(path: str, xs_tr, ys_tr, xs_te, ys_te):
    archive.write_archive(path, {
        "train_x": xs_tr, "train_y": ys_tr, "test_x": xs_te, "test_y": ys_te,
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--epochs", type=int, default=4)
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    print("[aot] generating synthetic datasets ...")
    mn_tr_x, mn_tr_y = data.synth_mnist(N_TRAIN, SEED)
    mn_te_x, mn_te_y = data.synth_mnist(N_TEST, SEED + 1)
    fa_tr_x, fa_tr_y = data.synth_fashion(N_TRAIN, SEED + 2)
    fa_te_x, fa_te_y = data.synth_fashion(N_TEST, SEED + 3)
    export_dataset(os.path.join(out, "mnist.bin"), mn_tr_x, mn_tr_y, mn_te_x, mn_te_y)
    export_dataset(os.path.join(out, "fashion.bin"), fa_tr_x, fa_tr_y, fa_te_x, fa_te_y)

    meta = {"t_steps": M.T_STEPS, "thresholds": list(train.INPUT_THRESHOLDS),
            "shapes": {k: list(v) if isinstance(v, tuple) else v
                       for k, v in M.SHAPES.items()},
            "datasets": {"n_train": N_TRAIN, "n_test": N_TEST},
            "accuracy": {}, "quant": {}}

    cache = os.path.join(out, "train_cache.npz")
    for ds_name, (tr_x, tr_y, te_x, te_y) in {
        "mnist": (mn_tr_x, mn_tr_y, mn_te_x, mn_te_y),
        "fashion": (fa_tr_x, fa_tr_y, fa_te_x, fa_te_y),
    }.items():
        ckey = f"{ds_name}_weights"
        cached = None
        if not args.retrain and os.path.exists(cache):
            z = np.load(cache, allow_pickle=True)
            if ckey in z:
                cached = list(z[ckey])
        if cached is None:
            print(f"[aot] training clamped-ReLU CNN on synthetic {ds_name} ...")
            weights = train.train_cnn(tr_x, tr_y, epochs=args.epochs, seed=SEED)
            flat = []
            for (w, b) in weights:
                flat.extend([np.asarray(w), np.asarray(b)])
            existing = {}
            if os.path.exists(cache):
                existing = dict(np.load(cache, allow_pickle=True))
            existing[ckey] = np.asarray(flat, dtype=object)
            np.savez(cache, **existing)
        else:
            print(f"[aot] using cached CNN weights for {ds_name}")
            flat = cached
            weights = [(jnp.asarray(flat[2 * i]), jnp.asarray(flat[2 * i + 1]))
                       for i in range(4)]

        ann_acc = train.evaluate_ann(weights, te_x[:N_EVAL], te_y[:N_EVAL])
        print(f"[aot] {ds_name}: ANN accuracy = {ann_acc:.4f}")
        snn = train.convert_to_snn(weights, tr_x[:256])
        snn = train.calibrate_vt(snn, tr_x[:200], tr_y[:200])
        snn_acc = train.evaluate_snn(snn, te_x[:N_EVAL], te_y[:N_EVAL])
        print(f"[aot] {ds_name}: SNN(float) accuracy = {snn_acc:.4f}")
        meta["accuracy"][ds_name] = {"ann": ann_acc, "snn_float": snn_acc}

        for bits in (8, 16):
            q, qi = quantize_and_record(snn, bits, meta, ds_name)
            qacc = train.evaluate_snn(q, te_x[:N_EVAL], te_y[:N_EVAL])
            meta["accuracy"][ds_name][f"snn_q{bits}"] = qacc
            print(f"[aot] {ds_name}: SNN(q{bits}) accuracy = {qacc:.4f}")
            suffix = "" if ds_name == "mnist" else "_fashion"
            export_params(os.path.join(out, f"weights_q{bits}{suffix}.bin"), q, True)
            if ds_name == "mnist":
                print(f"[aot] lowering quantized golden model (q{bits}) ...")
                hlo = lower_model(q, use_pallas=(bits == 8))
                with open(os.path.join(out, f"model_q{bits}.hlo.txt"), "w") as f:
                    f.write(hlo)
                if bits == 8:
                    sat = float(2 ** (qi.acc_bits - 1) - 1)
                    with open(os.path.join(out, "layer_step.hlo.txt"), "w") as f:
                        f.write(lower_layer_step(qi.vt_q[0], sat))
        suffix = "" if ds_name == "mnist" else "_fashion"
        export_params(os.path.join(out, f"weights_f32{suffix}.bin"), snn, False)

    with open(os.path.join(out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print("[aot] done.")


def quantize_and_record(snn, bits, meta, ds_name):
    q, qi = train.quantize_snn(snn, bits)
    meta["quant"][f"{ds_name}_q{bits}"] = {
        "bits": qi.bits, "acc_bits": qi.acc_bits,
        "scales": [float(s) for s in qi.scales],
        "fc_scale": float(qi.fc_scale),
        "vt_q": [float(v) for v in qi.vt_q],
        "sat_max": float(2 ** (qi.acc_bits - 1) - 1),
    }
    return q, qi


if __name__ == "__main__":
    main()
