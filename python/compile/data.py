"""Synthetic MNIST-like and Fashion-MNIST-like datasets (build-time only).

The build environment has no network access, so the paper's MNIST /
Fashion-MNIST downloads are substituted with deterministic procedural
generators (DESIGN.md §3). What matters for the reproduction is preserved:

  * 28x28 grayscale u8 frames, 10 balanced classes,
  * foreground/background structure so that m-TTFS binarization produces
    the sparse, class-informative spike trains the accelerator processes,
  * a digit-like stroke geometry (MNIST-like) and a texture/silhouette
    geometry (Fashion-like) so the two datasets differ in difficulty,
    mirroring the paper's accuracy gap between the two.

Absolute accuracies are reported as measured on these synthetic sets in
EXPERIMENTS.md; the paper's numbers are quoted alongside.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# MNIST-like digits: per-class stroke skeletons on a 20x20 box, rendered with
# jittered control points, thickness, shear and additive noise.
# ---------------------------------------------------------------------------

# Control polylines per digit on a unit square (x right, y down).
_DIGIT_STROKES = {
    0: [[(0.5, 0.05), (0.9, 0.3), (0.9, 0.7), (0.5, 0.95), (0.1, 0.7), (0.1, 0.3), (0.5, 0.05)]],
    1: [[(0.35, 0.25), (0.55, 0.05), (0.55, 0.95)], [(0.35, 0.95), (0.75, 0.95)]],
    2: [[(0.15, 0.25), (0.5, 0.05), (0.85, 0.25), (0.8, 0.5), (0.15, 0.95), (0.85, 0.95)]],
    3: [[(0.15, 0.1), (0.8, 0.1), (0.45, 0.45), (0.85, 0.7), (0.5, 0.95), (0.15, 0.85)]],
    4: [[(0.7, 0.95), (0.7, 0.05), (0.15, 0.65), (0.9, 0.65)]],
    5: [[(0.85, 0.05), (0.2, 0.05), (0.2, 0.45), (0.7, 0.45), (0.85, 0.7), (0.6, 0.95), (0.15, 0.9)]],
    6: [[(0.75, 0.05), (0.3, 0.4), (0.15, 0.75), (0.5, 0.95), (0.8, 0.75), (0.6, 0.5), (0.2, 0.65)]],
    7: [[(0.15, 0.05), (0.85, 0.05), (0.45, 0.95)], [(0.3, 0.5), (0.7, 0.5)]],
    8: [[(0.5, 0.05), (0.8, 0.25), (0.5, 0.48), (0.2, 0.25), (0.5, 0.05)],
        [(0.5, 0.48), (0.85, 0.72), (0.5, 0.95), (0.15, 0.72), (0.5, 0.48)]],
    9: [[(0.8, 0.35), (0.5, 0.55), (0.2, 0.35), (0.5, 0.05), (0.8, 0.35), (0.75, 0.95)]],
}


def _render_polyline(img: np.ndarray, pts: np.ndarray, thickness: float, value: float):
    """Rasterize a polyline with the given stroke thickness (in pixels)."""
    h, w = img.shape
    yy, xx = np.mgrid[0:h, 0:w]
    for (x0, y0), (x1, y1) in zip(pts[:-1], pts[1:]):
        # distance from each pixel to the segment
        dx, dy = x1 - x0, y1 - y0
        seg_len2 = dx * dx + dy * dy + 1e-9
        t = ((xx - x0) * dx + (yy - y0) * dy) / seg_len2
        t = np.clip(t, 0.0, 1.0)
        px, py = x0 + t * dx, y0 + t * dy
        d2 = (xx - px) ** 2 + (yy - py) ** 2
        mask = d2 <= thickness * thickness
        img[mask] = np.maximum(img[mask], value)


def synth_mnist(n: int, seed: int):
    """n synthetic digit images. Returns (x: (n,28,28) u8, y: (n,) u8)."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 28, 28), np.uint8)
    ys = rng.integers(0, 10, size=n).astype(np.uint8)
    for i in range(n):
        digit = int(ys[i])
        img = np.zeros((28, 28), np.float32)
        scale = rng.uniform(16.0, 21.0)
        ox = rng.uniform(3.0, 25.0 - scale * 0.9)
        oy = rng.uniform(3.0, 25.0 - scale * 0.95)
        shear = rng.uniform(-0.15, 0.15)
        thickness = rng.uniform(1.1, 1.9)
        for stroke in _DIGIT_STROKES[digit]:
            pts = np.array(stroke, np.float32)
            pts = pts + rng.normal(0.0, 0.02, pts.shape)  # control-point jitter
            px = ox + (pts[:, 0] + shear * pts[:, 1]) * scale
            py = oy + pts[:, 1] * scale
            _render_polyline(img, np.stack([px, py], -1), thickness, 1.0)
        img *= rng.uniform(0.75, 1.0)
        img += rng.normal(0.0, 0.03, img.shape)  # sensor noise
        xs[i] = (np.clip(img, 0.0, 1.0) * 255).astype(np.uint8)
    return xs, ys


# ---------------------------------------------------------------------------
# Fashion-like: 10 garment silhouettes (filled masks) with per-class aspect
# and texture statistics — harder than the stroke digits, like the real
# Fashion-MNIST is harder than MNIST.
# ---------------------------------------------------------------------------

def _silhouette(cls: int, rng) -> np.ndarray:
    """Filled 28x28 float mask for one of 10 garment-like classes."""
    img = np.zeros((28, 28), np.float32)
    yy, xx = np.mgrid[0:28, 0:28].astype(np.float32)
    cx = 14 + rng.uniform(-1.5, 1.5)
    cy = 14 + rng.uniform(-1.5, 1.5)

    def rect(x0, y0, x1, y1):
        return (xx >= x0) & (xx <= x1) & (yy >= y0) & (yy <= y1)

    w = rng.uniform(0.85, 1.15)
    h = rng.uniform(0.85, 1.15)
    if cls == 0:   # t-shirt: torso + short sleeves
        m = rect(cx - 5 * w, cy - 6 * h, cx + 5 * w, cy + 8 * h)
        m |= rect(cx - 9 * w, cy - 6 * h, cx + 9 * w, cy - 2 * h)
    elif cls == 1:  # trouser: two legs
        m = rect(cx - 5 * w, cy - 9 * h, cx + 5 * w, cy - 4 * h)
        m |= rect(cx - 5 * w, cy - 4 * h, cx - 1 * w, cy + 9 * h)
        m |= rect(cx + 1 * w, cy - 4 * h, cx + 5 * w, cy + 9 * h)
    elif cls == 2:  # pullover: torso + long sleeves
        m = rect(cx - 5 * w, cy - 6 * h, cx + 5 * w, cy + 8 * h)
        m |= rect(cx - 10 * w, cy - 6 * h, cx + 10 * w, cy + 3 * h)
    elif cls == 3:  # dress: narrow top widening down
        m = (np.abs(xx - cx) <= (2.5 + (yy - (cy - 9 * h)) * 0.38) * w) & (yy >= cy - 9 * h) & (yy <= cy + 9 * h)
    elif cls == 4:  # coat: wide torso + collar notch
        m = rect(cx - 6 * w, cy - 8 * h, cx + 6 * w, cy + 9 * h)
        m &= ~rect(cx - 1.2, cy - 8 * h, cx + 1.2, cy - 4 * h)
    elif cls == 5:  # sandal: strappy horizontal bars
        m = rect(cx - 9 * w, cy + 2, cx + 9 * w, cy + 6)
        m |= rect(cx - 7 * w, cy - 4, cx - 3 * w, cy + 2)
        m |= rect(cx + 1 * w, cy - 4, cx + 5 * w, cy + 2)
    elif cls == 6:  # shirt: torso + sleeves + button line
        m = rect(cx - 5 * w, cy - 7 * h, cx + 5 * w, cy + 8 * h)
        m |= rect(cx - 9 * w, cy - 7 * h, cx + 9 * w, cy - 1 * h)
        m &= ~((np.abs(xx - cx) < 0.7) & (yy > cy - 3 * h))
    elif cls == 7:  # sneaker: low wedge
        m = (yy >= cy + 1) & (yy <= cy + 7) & (xx >= cx - 9 * w) & (xx <= cx + 9 * w)
        m &= yy >= cy + 1 + (cx + 9 * w - xx) * 0.25
    elif cls == 8:  # bag: box + handle
        m = rect(cx - 8 * w, cy - 2, cx + 8 * w, cy + 8)
        ring = ((xx - cx) ** 2 / (5.5 * w) ** 2 + (yy - (cy - 4)) ** 2 / 4.5 ** 2)
        m |= (ring <= 1.0) & (ring >= 0.45)
    else:           # ankle boot: shaft + foot
        m = rect(cx - 2 * w, cy - 8 * h, cx + 5 * w, cy + 6)
        m |= rect(cx - 9 * w, cy + 1, cx + 5 * w, cy + 6)
    img[m] = 1.0
    return img


def synth_fashion(n: int, seed: int):
    """n synthetic garment images. Returns (x: (n,28,28) u8, y: (n,) u8)."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 28, 28), np.uint8)
    ys = rng.integers(0, 10, size=n).astype(np.uint8)
    for i in range(n):
        cls = int(ys[i])
        img = _silhouette(cls, rng)
        # per-class texture: garment classes have cloth-like intensity
        base = rng.uniform(0.55, 0.9)
        tex = rng.normal(0.0, 0.12, img.shape) + 0.08 * np.sin(
            np.linspace(0, rng.uniform(2, 9) * np.pi, 28)
        )[None, :]
        img = img * np.clip(base + tex, 0.15, 1.0)
        img += rng.normal(0.0, 0.04, img.shape)
        xs[i] = (np.clip(img, 0.0, 1.0) * 255).astype(np.uint8)
    return xs, ys
