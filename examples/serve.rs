//! Batched serving demo: start the coordinator over a **heterogeneous**
//! pool — a multi-core sharded simulator worker, a plain ×8 simulator
//! worker, and one dense-reference shadow worker behind the same queue —
//! fire a bursty synthetic request stream at it, and report latency
//! percentiles, batch-dispatch behaviour (sizes, per-batch service time,
//! worker-side images/sec), backpressure events and which backends
//! served the traffic.
//!
//! Every worker drains dynamic batches and serves them through one
//! `Backend::infer_batch` call; the sharded worker additionally fans its
//! batch out across host cores (see `lib.rs` §Throughput).
//!
//! Run with: `cargo run --release --example serve [n_requests]`

use sacsnn::coordinator::{Coordinator, ServerConfig};
use sacsnn::engine::{BackendKind, EngineBuilder, EngineError};
use sacsnn::report;
use sacsnn::util::prng::Pcg;
use sacsnn::Result;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let (net, ds, _) = report::env("mnist", 8)?;
    let cfg = ServerConfig { lanes: 8, queue_depth: 64, batch_size: 8, ..Default::default() };

    // Heterogeneous pool behind one queue:
    //   worker 0: sim sharded over 4 host cores (batches fan out),
    //   worker 1: plain single-core ×8 sim,
    //   worker 2: functional dense-ref shadow (online cross-check).
    let builder = EngineBuilder::new(Arc::clone(&net)).lanes(cfg.lanes);
    let backends = vec![
        builder.clone().threads(4).build(BackendKind::Sim)?,
        builder.build(BackendKind::Sim)?,
        builder.build(BackendKind::DenseRef)?,
    ];
    println!(
        "coordinator: {} workers (1×sim sharded ×4 threads + 1×sim + 1×dense-ref shadow), \
         queue depth {}, max batch {}",
        backends.len(),
        cfg.queue_depth,
        cfg.batch_size
    );
    let coord = Coordinator::start_pool(backends, cfg)?;

    // Bursty open-loop load: Poisson-ish bursts with think time, so the
    // dynamic batcher sees everything from singletons to full batches.
    let mut rng = Pcg::new(2024);
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    let t0 = Instant::now();
    let mut sent = 0usize;
    while sent < n {
        let burst = 1 + rng.below(12);
        for _ in 0..burst.min(n - sent) {
            let frame = report::frame_for(&net, &ds, rng.below(ds.n_test()))?;
            match coord.try_submit(frame) {
                Ok(rx) => pending.push(rx),
                Err(EngineError::Busy) => rejected += 1,
                Err(e) => return Err(e),
            }
            sent += 1;
        }
        std::thread::sleep(Duration::from_micros(200 + rng.below(800) as u64));
    }

    let mut lat = Vec::with_capacity(pending.len());
    let mut served_by: BTreeMap<&'static str, usize> = BTreeMap::new();
    let mut batch_sizes: BTreeMap<usize, usize> = BTreeMap::new();
    for rx in pending {
        let r = rx.recv().expect("reply")?;
        *served_by.entry(r.backend).or_insert(0) += 1;
        *batch_sizes.entry(r.batch_size).or_insert(0) += 1;
        lat.push(r.queue_wait_us + r.service_us);
    }
    let wall = t0.elapsed();
    lat.sort_unstable();
    let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize];
    let snap = coord.metrics.snapshot();
    println!(
        "\nserved {} / {} requests in {:.2} s ({:.0} req/s), {} rejected by backpressure",
        lat.len(),
        n,
        wall.as_secs_f64(),
        lat.len() as f64 / wall.as_secs_f64(),
        rejected
    );
    print!("served by:");
    for (name, count) in &served_by {
        print!("  {name} ×{count}");
    }
    println!();
    print!("request batch sizes:");
    for (size, count) in &batch_sizes {
        print!("  {size}→{count}");
    }
    println!();
    println!(
        "latency (queue+batch service): p50 {} µs, p90 {} µs, p99 {} µs, max {} µs",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        lat.last().unwrap()
    );
    println!(
        "batch dispatch: {} batches, mean size {:.2}, mean service {:.0} µs \
         (max {} µs), worker-side {:.1} images/s",
        snap.batches,
        snap.mean_batch,
        snap.mean_batch_service_us,
        snap.max_batch_service_us,
        snap.batch_images_per_sec
    );
    println!(
        "mean simulated cycles/frame: {:.0} (→ {:.0} device-FPS @333 MHz)",
        snap.mean_sim_cycles,
        333e6 / snap.mean_sim_cycles
    );
    println!("metrics json: {}", snap.to_json());
    coord.shutdown();
    Ok(())
}
