//! Multi-tenant serving demo: one persistent [`Server`], two tenants
//! over the **same weights** (sharing a single compiled plan through the
//! server's plan cache) with a 3:1 weighted-fair split, streamed to by
//! long-lived [`Session`]s under a bursty open loop.
//!
//! Shows the serving layer end to end:
//!   * typed admission (quota rejections become
//!     `EngineError::TenantOverQuota`, handled by taking a result first),
//!   * ordered per-session results bit-exact with the network,
//!   * workers staying filled across batch boundaries (`stream_pulls`),
//!   * per-tenant metrics (completed / failed / quota rejections /
//!     queue depth / images-per-sec) plus the JSON snapshot `serve
//!     --json` emits.
//!
//! Run with: `cargo run --release --example serve [n_requests]`

use sacsnn::coordinator::{Server, ServerConfig, Session, TenantConfig};
use sacsnn::engine::EngineError;
use sacsnn::report;
use sacsnn::util::prng::Pcg;
use sacsnn::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let (net, ds, _) = report::env("mnist", 8)?;

    let server = Server::start(ServerConfig {
        workers: 3,
        lanes: 8,
        batch_size: 8,
        ..Default::default()
    })?;

    // Two tenants, same weights, 3:1 weighted-fair share and a tight
    // quota on the light tenant so backpressure is observable.
    let heavy = server.register_tenant(
        Arc::clone(&net),
        TenantConfig { weight: 3, max_inflight: 64, ..Default::default() },
    )?;
    let light = server.register_tenant(
        Arc::clone(&net),
        TenantConfig { weight: 1, max_inflight: 16, ..Default::default() },
    )?;
    // same weights → the plan cache hands both tenants ONE compiled plan
    assert!(Arc::ptr_eq(
        &server.tenant_plan(heavy)?,
        &server.tenant_plan(light)?
    ));
    println!(
        "server: 3 workers, 2 tenants (weights 3:1, quotas 64/16), \
         {} compiled plan(s) for 2 registrations",
        server.cached_plans()
    );

    let mut sessions: Vec<Session> =
        vec![server.open_session(heavy)?, server.open_session(light)?];
    let mut latencies: Vec<Vec<u64>> = vec![Vec::new(), Vec::new()];
    let mut quota_hits = [0usize; 2];

    // Bursty open-loop load, biased 2:1 toward the heavy tenant, with
    // think time so the injector sees everything from singletons to
    // full batches.
    let mut rng = Pcg::new(2024);
    let t0 = Instant::now();
    let mut sent = 0usize;
    while sent < n {
        let burst = 1 + rng.below(12);
        for _ in 0..burst.min(n - sent) {
            let which = usize::from(rng.below(3) == 0); // 0 = heavy, 1 = light
            let frame = report::frame_for(&net, &ds, rng.below(ds.n_test()))?;
            // typed backpressure: the canonical loop takes one finished
            // result per quota rejection, then retries the feed
            let mut reply_err: Option<EngineError> = None;
            let lat = &mut latencies[which];
            let hits = &mut quota_hits[which];
            sessions[which].feed_yielding(&frame, &mut |reply| {
                *hits += 1;
                match reply {
                    Ok(r) => lat.push(r.queue_wait_us + r.service_us),
                    Err(e) => reply_err = Some(e),
                }
            })?;
            if let Some(e) = reply_err {
                return Err(e);
            }
            sent += 1;
        }
        std::thread::sleep(Duration::from_micros(200 + rng.below(800) as u64));
    }
    for (which, session) in sessions.drain(..).enumerate() {
        for reply in session.finish() {
            let r = reply?;
            latencies[which].push(r.queue_wait_us + r.service_us);
        }
    }
    let wall = t0.elapsed();

    let served: usize = latencies.iter().map(Vec::len).sum();
    println!(
        "\nserved {served} / {n} requests in {:.2} s ({:.0} req/s); \
         quota backpressure events: heavy {}, light {}",
        wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64(),
        quota_hits[0],
        quota_hits[1],
    );
    for (name, lat) in ["heavy", "light"].iter().zip(latencies.iter_mut()) {
        lat.sort_unstable();
        if lat.is_empty() {
            continue;
        }
        let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize];
        println!(
            "  {name}: {} served, latency p50 {} µs, p90 {} µs, p99 {} µs",
            lat.len(),
            pct(0.50),
            pct(0.90),
            pct(0.99),
        );
    }

    let snap = server.snapshot();
    println!(
        "dispatch: {} batches (mean size {:.2}), {} stream pulls kept workers \
         filled across batch boundaries, worker-side {:.1} images/s",
        snap.service.batches,
        snap.service.mean_batch,
        snap.service.stream_pulls,
        snap.service.batch_images_per_sec,
    );
    for t in &snap.tenants {
        println!(
            "  tenant {} (weight {}): completed {}, failed {}, quota rejections {}, \
             queue depth {}, {:.1} images/s",
            t.tenant, t.weight, t.completed, t.failed, t.quota_rejected, t.queue_depth,
            t.images_per_sec,
        );
    }
    println!("metrics json: {}", snap.to_json());
    server.shutdown();
    Ok(())
}
