//! Serving demo: start the coordinator (router + dynamic batcher +
//! worker pool, each worker owning a ×8 simulated accelerator), fire a
//! bursty synthetic request stream at it, and report latency percentiles,
//! throughput, batching behaviour and backpressure events.
//!
//! Run with: `cargo run --release --example serve [n_requests]`

use anyhow::Result;
use sacsnn::coordinator::{Coordinator, ServerConfig, SubmitError};
use sacsnn::report;
use sacsnn::util::prng::Pcg;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let (net, ds, _) = report::env("mnist", 8)?;
    let cfg = ServerConfig { workers: 4, lanes: 8, queue_depth: 64, batch_size: 8 };
    println!(
        "coordinator: {} workers × (accelerator ×{}), queue depth {}, max batch {}",
        cfg.workers, cfg.lanes, cfg.queue_depth, cfg.batch_size
    );
    let coord = Coordinator::start(net, cfg);

    // Bursty open-loop load: Poisson-ish bursts with think time.
    let mut rng = Pcg::new(2024);
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    let t0 = Instant::now();
    let mut sent = 0usize;
    while sent < n {
        let burst = 1 + rng.below(12);
        for _ in 0..burst.min(n - sent) {
            let img = ds.test_image(rng.below(ds.n_test())).to_vec();
            match coord.try_submit(img) {
                Ok(rx) => pending.push(rx),
                Err(SubmitError::Busy) => rejected += 1,
                Err(e) => return Err(e.into()),
            }
            sent += 1;
        }
        std::thread::sleep(Duration::from_micros(200 + rng.below(800) as u64));
    }

    let mut lat: Vec<u64> = pending
        .into_iter()
        .map(|rx| {
            let r = rx.recv().expect("reply");
            r.queue_wait_us + r.service_us
        })
        .collect();
    let wall = t0.elapsed();
    lat.sort_unstable();
    let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize];
    let snap = coord.metrics.snapshot();
    println!("\nserved {} / {} requests in {:.2} s ({:.0} req/s), {} rejected by backpressure",
        lat.len(), n, wall.as_secs_f64(), lat.len() as f64 / wall.as_secs_f64(), rejected);
    println!("latency (queue+service): p50 {} µs, p90 {} µs, p99 {} µs, max {} µs",
        pct(0.50), pct(0.90), pct(0.99), lat.last().unwrap());
    println!("dynamic batching: {} batches, mean size {:.2}", snap.batches, snap.mean_batch);
    println!("mean simulated cycles/frame: {:.0} (→ {:.0} device-FPS @333 MHz)",
        snap.mean_sim_cycles, 333e6 / snap.mean_sim_cycles);
    println!("metrics json: {}", snap.to_json());
    coord.shutdown();
    Ok(())
}
