//! End-to-end validation driver (DESIGN.md "End-to-end validation"):
//! evaluates the full stack on the synthetic MNIST test set —
//! accuracy, throughput, per-layer statistics — and cross-checks the
//! cycle-level simulator against BOTH the Rust dense reference and the
//! AOT-lowered JAX/Pallas golden model via PJRT (skipped without the
//! `pjrt` feature).
//!
//! Run with: `cargo run --release --example mnist_pipeline [n_images]`

use sacsnn::cost::power::PowerModel;
use sacsnn::cost::CLOCK_HZ;
use sacsnn::engine::{Backend as _, BackendKind, EngineBuilder, EngineError};
use sacsnn::report;
use sacsnn::Result;
use std::sync::Arc;
use std::time::Instant;

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let (net, ds, meta) = report::env("mnist", 8)?;
    let n = n.min(ds.n_test());

    println!("== 1. accuracy + throughput over {n} synthetic MNIST test images ==");
    let builder = EngineBuilder::new(Arc::clone(&net));
    let mut accel = builder.lanes(8).build(BackendKind::Sim)?;
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut cycles = 0u64;
    let mut busy = 0u64;
    let mut unit = 0u64;
    for i in 0..n {
        let r = accel.infer(&report::frame_for(&net, &ds, i)?)?;
        correct += (r.pred == ds.test_y[i] as usize) as usize;
        cycles += r.stats.total_cycles;
        for l in &r.stats.layers {
            busy += l.pe_busy;
            unit += l.conv_cycles + l.thresh_cycles;
        }
    }
    let wall = t0.elapsed();
    let avg = cycles as f64 / n as f64;
    let util = busy as f64 / unit as f64;
    let watts = PowerModel::new(8, 8).watts(util);
    println!("accuracy        : {}/{} = {:.2}%", correct, n, 100.0 * correct as f64 / n as f64);
    println!("  (build-time python: SNN q8 {:.2}%, ANN {:.2}%)",
        meta.accuracy("mnist").snn_q8 * 100.0, meta.accuracy("mnist").ann * 100.0);
    println!("avg cycles/frame: {avg:.0} → {:.0} FPS @333 MHz, {:.3} ms latency",
        CLOCK_HZ / avg, avg / CLOCK_HZ * 1e3);
    println!("PE utilization  : {:.1}%   power model: {watts:.2} W → {:.0} FPS/W",
        util * 100.0, CLOCK_HZ / avg / watts);
    println!("host simulation : {:.1} img/s", n as f64 / wall.as_secs_f64());

    println!("\n== 2. simulator vs Rust dense reference (spike-exact) ==");
    let mut reference = EngineBuilder::new(Arc::clone(&net)).build(BackendKind::DenseRef)?;
    let m = n.min(25);
    for i in 0..m {
        let frame = report::frame_for(&net, &ds, i)?;
        let want = reference.infer(&frame)?;
        let got = accel.infer(&frame)?;
        assert_eq!(got.logits, want.logits, "logits diverged at image {i}");
        assert_eq!(
            got.stats.spike_counts, want.stats.spike_counts,
            "spike counts diverged at image {i}"
        );
    }
    println!("{m}/{m} images match the dense reference exactly");

    println!("\n== 3. simulator vs AOT JAX/Pallas golden model (PJRT) ==");
    match report::golden_check(m.min(10), BackendKind::Sim) {
        Ok(out) => print!("{out}"),
        Err(EngineError::Unavailable(why)) => println!("skipped: {why}"),
        Err(e) => return Err(e),
    }

    println!("\nall layers compose: kernel (L1) == model (L2) == simulator (L3).");
    Ok(())
}
