//! Quickstart: load the quantized network + dataset artifacts, run one
//! image through the simulated accelerator, and print what happened —
//! prediction, cycle breakdown, sparsity, PE utilization, and the
//! Fig. 2-style m-TTFS membrane trace.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first).

use anyhow::Result;
use sacsnn::report;
use sacsnn::sim::{AccelConfig, Accelerator};
use std::sync::Arc;

fn main() -> Result<()> {
    let (net, ds, meta) = report::env("mnist", 8)?;
    println!(
        "loaded: paper network 28x28-32C3-32C3-P3-10C3-F10, q8 (scales from meta.json), T = {}",
        meta.t_steps
    );

    let mut accel = Accelerator::new(
        Arc::clone(&net),
        AccelConfig { lanes: 8, ..Default::default() },
    );
    let img = ds.test_image(0);
    let res = accel.infer(img);
    println!("\nimage #0 (label {}):", ds.test_y[0]);
    println!("  prediction      : {}", res.pred);
    println!("  logits          : {:?}", res.logits);
    println!("  total cycles    : {}", res.stats.total_cycles);
    println!("  FPS @ 333 MHz   : {:.0}", res.stats.fps(333e6));
    println!("  latency         : {:.3} ms", res.stats.latency_s(333e6) * 1e3);
    for (i, l) in res.stats.layers.iter().enumerate() {
        println!(
            "  layer {}: {} events, sparsity {:.1}%, PE utilization {:.1}%, {} stalls",
            i + 1,
            l.events,
            l.input_sparsity * 100.0,
            l.pe_utilization() * 100.0,
            l.stalls
        );
    }

    println!("\n{}", report::trace_neuron(0)?);
    Ok(())
}
