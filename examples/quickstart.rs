//! Quickstart: load the quantized network + dataset artifacts, pick a
//! backend from the engine registry (first arg, default `sim`), run one
//! image through it, and print what happened — prediction, cycle
//! breakdown, sparsity, PE utilization, and the Fig. 2-style m-TTFS
//! membrane trace.
//!
//! Run with: `cargo run --release --example quickstart [backend]`
//! (requires `make artifacts` first).

use sacsnn::engine::{Backend as _, BackendKind, EngineBuilder};
use sacsnn::report;
use sacsnn::Result;
use std::sync::Arc;

fn main() -> Result<()> {
    let kind = match std::env::args().nth(1) {
        Some(name) => BackendKind::parse(&name)?,
        None => BackendKind::Sim,
    };
    let (net, ds, meta) = report::env("mnist", 8)?;
    println!(
        "loaded: paper network 28x28-32C3-32C3-P3-10C3-F10, q8 (scales from meta.json), T = {}",
        meta.t_steps
    );

    let mut backend = EngineBuilder::new(Arc::clone(&net)).lanes(8).build(kind)?;
    let cm = backend.cycle_model();
    println!(
        "backend: {} ({} PEs, {}, {})",
        backend.name(),
        cm.n_pes,
        if cm.event_driven { "event-driven" } else { "frame-based" },
        if cm.cycle_accurate { "cycle-accurate" } else { "functional golden" },
    );

    let res = backend.infer(&report::frame_for(&net, &ds, 0)?)?;
    println!("\nimage #0 (label {}):", ds.test_y[0]);
    println!("  prediction      : {}", res.pred);
    println!("  logits          : {:?}", res.logits);
    if cm.cycle_accurate {
        println!("  total cycles    : {}", res.stats.total_cycles);
        println!("  FPS @ {:.0} MHz   : {:.0}", cm.clock_hz / 1e6, res.stats.fps(cm.clock_hz));
        println!("  latency         : {:.3} ms", res.stats.latency_s(cm.clock_hz) * 1e3);
    }
    for (i, l) in res.stats.layers.iter().enumerate() {
        println!(
            "  layer {}: {} events, sparsity {:.1}%, PE utilization {:.1}%, {} stalls",
            i + 1,
            l.events,
            l.input_sparsity * 100.0,
            l.pe_utilization() * 100.0,
            l.stalls
        );
    }

    println!("\n{}", report::trace_neuron(0)?);
    Ok(())
}
