//! Parallelization sweep (the Table-I experiment as an example binary):
//! runs the simulator at ×1 … ×16, prints FPS / power / FPS-per-watt next
//! to the paper's published values, and verifies the qualitative shape
//! (monotone FPS, efficiency peak at ×8).
//!
//! Run with: `cargo run --release --example sweep [n_images]`

use sacsnn::cost::power::TABLE1_PAPER;
use sacsnn::report::{self, measure};
use sacsnn::Result;

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let (net, ds, _) = report::env("mnist", 8)?;
    println!("{:<6} {:>12} {:>9} {:>9} {:>10} | {:>12} {:>12}",
        "par", "FPS(sim)", "util", "W", "FPS/W", "FPS(paper)", "FPS/W(paper)");
    let mut effs = Vec::new();
    let mut fpss = Vec::new();
    for (lanes, pf, pe) in TABLE1_PAPER {
        let p = measure(&net, &ds, lanes, n);
        println!("x{:<5} {:>12.0} {:>8.1}% {:>9.2} {:>10.0} | {:>12.0} {:>12.0}",
            lanes, p.fps, p.utilization * 100.0, p.watts, p.eff, pf, pe);
        effs.push(p.eff);
        fpss.push(p.fps);
    }
    assert!(fpss.windows(2).all(|w| w[1] > w[0]), "FPS must be monotone in P");
    let peak = effs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!("\nefficiency peak at ×{} (paper: ×8)", [1, 2, 4, 8, 16][peak]);
    Ok(())
}
