#!/usr/bin/env python3
"""CI perf-regression gate + baseline ratchet for BENCH_sim.json.

Modes:
    python3 ci/perf_gate.py [--current BENCH_sim.json] [--baseline BENCH_baseline.json]
        Gate: compare the measured artifact against the committed
        baseline (exit 1 on regression).
    python3 ci/perf_gate.py --ratchet BENCH_sim.json [--baseline ...] [--out ...]
        Ratchet: emit a TIGHTENED baseline from a green run's artifact —
        each throughput floor becomes ``0.85 × measured`` (but floors
        never loosen: the old floor wins if it is already higher), each
        latency ceiling becomes ``measured / 0.85`` (but ceilings never
        rise: the old ceiling wins if it is already lower), and the
        alloc ceiling becomes ``min(old, measured)``. This is the
        mechanized version of the procedure the baseline's ``_note``
        documents.
    python3 ci/perf_gate.py --selftest
        Unit-style self-test of the gate and ratchet math (plain
        python3, no deps; exit 0 = pass). CI runs this in tier-1 so the
        gate itself can never silently rot.

Gate rules (tolerances chosen for shared CI runners):
  * ``frames_per_s``             — fail on a drop of more than 15% vs baseline
  * ``images_per_sec_batched``   — fail on a drop of more than 15% vs baseline
  * ``images_per_sec_pipelined`` — fail on a drop of more than 15% vs baseline
  * ``images_per_sec_cifar``     — fail on a drop of more than 15% vs baseline
    (the CIFAR-shaped layer-zoo path; previously emitted but ungated, so a
    regression there was invisible to CI)
  * ``replay_p99_us``            — fail on a RISE of more than 50% vs baseline
    (trace-replay p99 submit→reply latency; tail latency is noisier than
    mean throughput on shared runners, hence the wider tolerance)
  * ``replay_availability``      — fail on ANY drop (served/fed ratio of the
    gated fault-free replay; there is no runner noise in whether a frame
    was answered, so the floor — 1.0 in the committed baseline — is exact)
  * ``allocs_per_inference``     — fail on ANY increase (the zero-allocation
    execute step is machine-independent: an increase is always a real
    regression, never runner noise)

Every throughput floor and latency ceiling is a HARD gate; a gated field
missing from either file also fails (a renamed bench field cannot
silently un-enforce its floor or ceiling). The full field-by-field diff is printed and, when running inside
GitHub Actions, appended to the step summary.

Exit status: 0 = pass, 1 = regression/selftest failure, 2 = bad input.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

THROUGHPUT_DROP_TOLERANCE = 0.15  # >15% drop fails
LATENCY_RISE_TOLERANCE = 0.50  # >50% rise over a latency ceiling fails
RATCHET_HEADROOM = 0.85  # ratcheted floor = 0.85 × measured; ceiling = measured / 0.85
THROUGHPUT_FIELDS = (
    "frames_per_s",
    "images_per_sec_batched",
    "images_per_sec_pipelined",
    "images_per_sec_cifar",
)
# Tail-latency CEILINGS (lower is better): the trace-replay p99 of
# submit→reply latency from the bench's seeded multi-tenant replay.
LATENCY_FIELDS = ("replay_p99_us",)
# Availability FLOORS with NO tolerance (the serving layer either
# answered a fed frame with a result or it did not — there is no runner
# noise in that ratio): current must be >= baseline exactly. The bench's
# gated replay runs without fault injection, so the committed floor is
# 1.0 — any frame failing typed in CI is a real serving regression.
AVAILABILITY_FIELDS = ("replay_availability",)
ALLOC_FIELD = "allocs_per_inference"

RATCHET_NOTE = (
    "Perf-gate baseline (see ci/perf_gate.py). allocs_per_inference is exact "
    "and machine-independent: any increase always fails the gate. The "
    "throughput floors are HARD gates: >15% below any of them fails CI. "
    "replay_p99_us is a HARD tail-latency ceiling: >50% above it fails CI. "
    "replay_availability is an exact zero-tolerance floor: any served-frame "
    "failure in the gated replay fails CI. "
    "Ratcheted from a green run's BENCH_sim artifact via "
    "`python3 ci/perf_gate.py --ratchet BENCH_sim.json`: each floor is 0.85 x "
    "the measured value of that run (floors never loosen) and each latency "
    "ceiling is measured / 0.85 (ceilings never rise), so the gate tightens "
    "as the hot path gets faster."
)


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def evaluate(cur: dict, base: dict):
    """Gate `cur` against `base`; returns (report_rows, failures)."""
    failures: list[str] = []
    rows: list[tuple[str, str, str, str, str]] = []

    def row(field, baseline, current, delta, verdict):
        rows.append((field, baseline, current, delta, verdict))

    for field in THROUGHPUT_FIELDS:
        b, c = base.get(field), cur.get(field)
        if b is None or c is None:
            # A gated field going missing is itself a regression: the
            # hard floor would otherwise silently stop being enforced.
            row(field, str(b), str(c), "-", "FAIL (missing)")
            failures.append(
                f"{field}: missing from {'baseline' if b is None else 'current'} "
                "(gated fields must be present in both files)"
            )
            continue
        floor = b * (1.0 - THROUGHPUT_DROP_TOLERANCE)
        delta = (c - b) / b * 100.0 if b else float("inf")
        ok = c >= floor
        row(field, f"{b:.1f}", f"{c:.1f}", f"{delta:+.1f}%", "ok" if ok else "FAIL")
        if not ok:
            failures.append(
                f"{field}: {c:.1f} is below the {THROUGHPUT_DROP_TOLERANCE:.0%}"
                f"-tolerance floor {floor:.1f} (baseline {b:.1f})"
            )

    for field in LATENCY_FIELDS:
        b, c = base.get(field), cur.get(field)
        if b is None or c is None:
            row(field, str(b), str(c), "-", "FAIL (missing)")
            failures.append(
                f"{field}: missing from {'baseline' if b is None else 'current'} "
                "(gated fields must be present in both files)"
            )
            continue
        ceiling = b * (1.0 + LATENCY_RISE_TOLERANCE)
        delta = (c - b) / b * 100.0 if b else float("inf")
        ok = c <= ceiling
        row(field, f"{b:.1f}", f"{c:.1f}", f"{delta:+.1f}%" if b else "-", "ok" if ok else "FAIL")
        if not ok:
            failures.append(
                f"{field}: {c:.1f} is above the {LATENCY_RISE_TOLERANCE:.0%}"
                f"-tolerance ceiling {ceiling:.1f} (baseline {b:.1f})"
            )

    for field in AVAILABILITY_FIELDS:
        b, c = base.get(field), cur.get(field)
        if b is None or c is None:
            row(field, str(b), str(c), "-", "FAIL (missing)")
            failures.append(
                f"{field}: missing from {'baseline' if b is None else 'current'} "
                "(gated fields must be present in both files)"
            )
            continue
        ok = c >= b - 1e-9
        delta = f"{(c - b) * 100.0:+.2f}pp"
        row(field, f"{b:.4f}", f"{c:.4f}", delta, "ok" if ok else "FAIL")
        if not ok:
            failures.append(
                f"{field}: {c:.4f} is below the zero-tolerance floor {b:.4f} "
                "(every fed frame must be answered with a result)"
            )

    b, c = base.get(ALLOC_FIELD), cur.get(ALLOC_FIELD)
    if b is None or c is None:
        row(ALLOC_FIELD, str(b), str(c), "-", "FAIL (missing)")
        failures.append(
            f"{ALLOC_FIELD}: missing from {'baseline' if b is None else 'current'} "
            "(gated fields must be present in both files)"
        )
    else:
        ok = c <= b + 1e-9
        row(ALLOC_FIELD, f"{b:.3f}", f"{c:.3f}", f"{c - b:+.3f}", "ok" if ok else "FAIL")
        if not ok:
            failures.append(
                f"{ALLOC_FIELD}: increased from {b:.3f} to {c:.3f} "
                "(any increase fails — the execute step must stay allocation-free)"
            )

    # Informational fields: everything numeric the two files share.
    for field in sorted(set(cur) & set(base)):
        if (
            field in THROUGHPUT_FIELDS
            or field in LATENCY_FIELDS
            or field in AVAILABILITY_FIELDS
            or field == ALLOC_FIELD
        ):
            continue
        b, c = base[field], cur[field]
        if isinstance(b, (int, float)) and isinstance(c, (int, float)) and not isinstance(b, bool):
            delta = f"{(c - b) / b * 100.0:+.1f}%" if b else "-"
            row(field, f"{b}", f"{c}", delta, "info")

    return rows, failures


def ratchet(measured: dict, base: dict) -> dict:
    """Tightened baseline from a green run's artifact.

    Floors become ``RATCHET_HEADROOM × measured`` but never loosen;
    latency ceilings become ``measured / RATCHET_HEADROOM`` but never
    rise; the alloc ceiling becomes ``min(old, measured)``.
    Informational fields are refreshed from the measured artifact.
    Raises ValueError if a gated field is missing from the measurement.
    """
    missing = [f for f in gated_fields() if f not in measured]
    if missing:
        raise ValueError(f"measured artifact is missing gated fields: {missing}")
    out = dict(measured)
    out.pop("_note", None)
    new_base = {"_note": RATCHET_NOTE}
    for field in THROUGHPUT_FIELDS:
        floor = round(RATCHET_HEADROOM * float(measured[field]), 3)
        old = base.get(field)
        if isinstance(old, (int, float)) and not isinstance(old, bool):
            floor = max(floor, float(old))  # a ratchet only tightens
        new_base[field] = floor
    for field in LATENCY_FIELDS:
        ceiling = round(float(measured[field]) / RATCHET_HEADROOM, 3)
        old = base.get(field)
        if isinstance(old, (int, float)) and not isinstance(old, bool):
            ceiling = min(ceiling, float(old))  # a ratchet only tightens
        new_base[field] = ceiling
    for field in AVAILABILITY_FIELDS:
        floor = float(measured[field])
        old = base.get(field)
        if isinstance(old, (int, float)) and not isinstance(old, bool):
            floor = max(floor, float(old))  # a ratchet only tightens
        new_base[field] = floor
    old_alloc = base.get(ALLOC_FIELD)
    alloc = float(measured[ALLOC_FIELD])
    if isinstance(old_alloc, (int, float)) and not isinstance(old_alloc, bool):
        alloc = min(alloc, float(old_alloc))
    new_base[ALLOC_FIELD] = alloc
    # carry the informational fields of the measured run
    for field, value in out.items():
        if field not in new_base:
            new_base[field] = value
    return new_base


def render(rows, failures) -> str:
    header = ("field", "baseline", "current", "delta", "verdict")
    md = ["| " + " | ".join(header) + " |", "|" + "---|" * len(header)]
    md += ["| " + " | ".join(r) + " |" for r in rows]
    verdict = "PASS" if not failures else "FAIL:\n  " + "\n  ".join(failures)
    return "### Perf gate\n\n" + "\n".join(md) + f"\n\n**{verdict}**\n"


def gated_fields() -> tuple:
    """Every field the gate hard-enforces, in report order."""
    return (*THROUGHPUT_FIELDS, *LATENCY_FIELDS, *AVAILABILITY_FIELDS, ALLOC_FIELD)


def selftest() -> int:
    """Unit-style checks of the gate and ratchet math, plus a sync check
    of the gated-field list against the committed baseline (plain
    python3, no deps)."""
    base = {
        "frames_per_s": 100.0,
        "images_per_sec_batched": 200.0,
        "images_per_sec_pipelined": 150.0,
        "images_per_sec_cifar": 50.0,
        "replay_p99_us": 1000.0,
        "replay_availability": 1.0,
        "allocs_per_inference": 0.0,
        "frames": 20,
    }

    def gate_fails(cur):
        return bool(evaluate(cur, base)[1])

    checks = []

    def check(name, ok):
        checks.append((name, ok))
        print(f"  {'ok' if ok else 'FAIL'}  {name}")

    same = dict(base)
    check("identical run passes", not gate_fails(same))

    at_floor = dict(base, frames_per_s=85.0)
    check("drop of exactly 15% passes (floor is inclusive)", not gate_fails(at_floor))

    below = dict(base, frames_per_s=84.9)
    check("drop past 15% fails", gate_fails(below))

    cifar_below = dict(base, images_per_sec_cifar=42.0)
    check("cifar throughput drop past 15% fails", gate_fails(cifar_below))

    missing_cifar = dict(base)
    del missing_cifar["images_per_sec_cifar"]
    check("missing cifar field fails (newly gated field)", gate_fails(missing_cifar))

    alloc_up = dict(base, allocs_per_inference=0.001)
    check("ANY alloc increase fails", gate_fails(alloc_up))

    missing = dict(base)
    del missing["images_per_sec_pipelined"]
    check("missing gated field fails", gate_fails(missing))

    faster = dict(base, frames_per_s=1000.0)
    check("faster run passes", not gate_fails(faster))

    at_ceiling = dict(base, replay_p99_us=1500.0)
    check("p99 rise of exactly 50% passes (ceiling is inclusive)", not gate_fails(at_ceiling))

    over_ceiling = dict(base, replay_p99_us=1500.1)
    check("p99 rise past 50% fails", gate_fails(over_ceiling))

    lower_p99 = dict(base, replay_p99_us=1.0)
    check("lower tail latency passes", not gate_fails(lower_p99))

    missing_lat = dict(base)
    del missing_lat["replay_p99_us"]
    check("missing latency field fails", gate_fails(missing_lat))

    dropped_avail = dict(base, replay_availability=0.9999)
    check("ANY availability drop fails (zero tolerance)", gate_fails(dropped_avail))

    at_avail_floor = dict(base, replay_availability=1.0)
    check("availability exactly at the floor passes", not gate_fails(at_avail_floor))

    missing_avail = dict(base)
    del missing_avail["replay_availability"]
    check("missing availability field fails", gate_fails(missing_avail))

    measured = {
        "frames_per_s": 200.0,
        "images_per_sec_batched": 100.0,  # slower than the old 200 floor
        "images_per_sec_pipelined": 300.0,
        "images_per_sec_cifar": 80.0,
        "replay_p99_us": 425.0,  # faster than the old 1000 µs ceiling
        "replay_availability": 1.0,
        "allocs_per_inference": 0.0,
        "frames": 20,
        "smoke": True,
    }
    new_base = ratchet(measured, base)
    check(
        "ratchet floor = 0.85 x measured when tightening",
        new_base["frames_per_s"] == round(0.85 * 200.0, 3),
    )
    check(
        "ratchet never loosens an existing floor",
        new_base["images_per_sec_batched"] == 200.0,
    )
    check("ratchet keeps the alloc ceiling at min(old, measured)",
          new_base[ALLOC_FIELD] == 0.0)
    check(
        "ratchet latency ceiling = measured / 0.85 when tightening",
        new_base["replay_p99_us"] == round(425.0 / 0.85, 3),
    )
    check(
        "ratchet never raises an existing latency ceiling",
        ratchet(dict(measured, replay_p99_us=10_000.0), base)["replay_p99_us"] == 1000.0,
    )
    check(
        "ratchet never lowers an existing availability floor",
        ratchet(dict(measured, replay_availability=0.97), base)["replay_availability"] == 1.0,
    )
    check(
        "ratchet availability floor rises to the measured value",
        ratchet(
            dict(measured, replay_availability=0.95),
            dict(base, replay_availability=0.9),
        )["replay_availability"]
        == 0.95,
    )
    check("ratchet carries informational fields", new_base["frames"] == 20)
    check("ratchet writes the procedure note", "_note" in new_base)
    # a measured run faster on every axis passes the baseline it ratchets
    all_faster = {f: 10.0 * base[f] for f in THROUGHPUT_FIELDS}
    all_faster["replay_p99_us"] = 100.0  # tail latency: faster = lower
    all_faster["replay_availability"] = 1.0
    all_faster[ALLOC_FIELD] = 0.0
    all_faster["frames"] = 20
    check(
        "ratcheted baseline passes its own measured run",
        not evaluate(all_faster, ratchet(all_faster, base))[1],
    )
    # ...while a run slower than a kept (never-loosened) floor still fails
    check(
        "kept floors still gate the slower run that produced them",
        bool(evaluate(measured, new_base)[1]),
    )
    try:
        ratchet({"frames_per_s": 1.0}, base)
        check("ratchet rejects artifacts missing gated fields", False)
    except ValueError:
        check("ratchet rejects artifacts missing gated fields", True)

    # Sync check: every gated field must exist as a key in the committed
    # baseline. The gate already hard-fails at *run* time when a gated
    # field is missing from either artifact, but that run only happens in
    # the perf job — this check makes the same drift (a bench field
    # renamed without updating BENCH_baseline.json, or a field added to
    # the gated tuples with no committed floor/ceiling) fail the cheap
    # tier-1 selftest too, with a message naming the missing key.
    committed = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                             "BENCH_baseline.json")
    try:
        with open(committed, encoding="utf-8") as f:
            committed_keys = set(json.load(f))
        stale = [field for field in gated_fields() if field not in committed_keys]
        check(
            "committed BENCH_baseline.json carries every gated field"
            + (f" (missing: {stale})" if stale else ""),
            not stale,
        )
        # And the self-test's own fixture baseline must model the real
        # one: a gate behavior proven here is only evidence about CI if
        # the fixture gates the same fields.
        fixture_stale = [field for field in gated_fields() if field not in base]
        check(
            "selftest fixture baseline carries every gated field"
            + (f" (missing: {fixture_stale})" if fixture_stale else ""),
            not fixture_stale,
        )
    except (OSError, ValueError) as e:
        check(f"committed BENCH_baseline.json is readable ({e})", False)

    failed = [name for name, ok in checks if not ok]
    print(f"selftest: {len(checks) - len(failed)}/{len(checks)} checks passed")
    return 1 if failed else 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_sim.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument(
        "--ratchet",
        metavar="BENCH_SIM_JSON",
        help="emit a tightened baseline from this green-run artifact instead of gating",
    )
    ap.add_argument(
        "--out",
        default="BENCH_baseline.json",
        help="where --ratchet writes the tightened baseline",
    )
    ap.add_argument("--selftest", action="store_true", help="run the gate/ratchet self-test")
    args = ap.parse_args()

    if args.selftest:
        return selftest()

    if args.ratchet:
        measured = load(args.ratchet)
        base = load(args.baseline) if os.path.exists(args.baseline) else {}
        try:
            new_base = ratchet(measured, base)
        except ValueError as e:
            print(f"perf gate: {e}", file=sys.stderr)
            return 2
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(new_base, f, indent=2)
            f.write("\n")
        print(f"ratcheted baseline written to {args.out}:")
        for field in THROUGHPUT_FIELDS:
            print(f"  {field}: floor {new_base[field]}")
        for field in LATENCY_FIELDS:
            print(f"  {field}: ceiling {new_base[field]}")
        for field in AVAILABILITY_FIELDS:
            print(f"  {field}: floor {new_base[field]}")
        print(f"  {ALLOC_FIELD}: ceiling {new_base[ALLOC_FIELD]}")
        return 0

    cur = load(args.current)
    base = load(args.baseline)
    rows, failures = evaluate(cur, base)
    report = render(rows, failures)
    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as f:
            f.write(report)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
