#!/usr/bin/env python3
"""CI perf-regression gate: BENCH_sim.json vs the committed baseline.

Usage:
    python3 ci/perf_gate.py [--current BENCH_sim.json] [--baseline BENCH_baseline.json]

Rules (tolerances chosen for shared CI runners):
  * ``frames_per_s``             — fail on a drop of more than 15% vs baseline
  * ``images_per_sec_batched``   — fail on a drop of more than 15% vs baseline
  * ``images_per_sec_pipelined`` — fail on a drop of more than 15% vs baseline
  * ``allocs_per_inference``     — fail on ANY increase (the zero-allocation
    execute step is machine-independent: an increase is always a real
    regression, never runner noise)

Every throughput floor is a HARD gate: a drop below the tolerance fails
the job. The committed floors are deliberately conservative (they catch
order-of-magnitude regressions on any runner, not few-percent drift);
ratchet them tighter by copying the ``BENCH_sim`` artifact of a green
main run over ``BENCH_baseline.json`` whenever the hot path gets faster.

The full field-by-field diff is printed and, when running inside GitHub
Actions, appended to the step summary.

Exit status: 0 = pass, 1 = regression, 2 = missing/invalid input.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

THROUGHPUT_DROP_TOLERANCE = 0.15  # >15% drop fails
THROUGHPUT_FIELDS = (
    "frames_per_s",
    "images_per_sec_batched",
    "images_per_sec_pipelined",
)
ALLOC_FIELD = "allocs_per_inference"


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_sim.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    args = ap.parse_args()

    cur = load(args.current)
    base = load(args.baseline)

    failures: list[str] = []
    rows: list[tuple[str, str, str, str, str]] = []

    def row(field, baseline, current, delta, verdict):
        rows.append((field, baseline, current, delta, verdict))

    for field in THROUGHPUT_FIELDS:
        b, c = base.get(field), cur.get(field)
        if b is None or c is None:
            # A gated field going missing is itself a regression: the
            # hard floor would otherwise silently stop being enforced.
            row(field, str(b), str(c), "-", "FAIL (missing)")
            failures.append(
                f"{field}: missing from {'baseline' if b is None else 'current'} "
                "(gated fields must be present in both files)"
            )
            continue
        floor = b * (1.0 - THROUGHPUT_DROP_TOLERANCE)
        delta = (c - b) / b * 100.0 if b else float("inf")
        ok = c >= floor
        row(field, f"{b:.1f}", f"{c:.1f}", f"{delta:+.1f}%", "ok" if ok else "FAIL")
        if not ok:
            failures.append(
                f"{field}: {c:.1f} is below the {THROUGHPUT_DROP_TOLERANCE:.0%}"
                f"-tolerance floor {floor:.1f} (baseline {b:.1f})"
            )

    b, c = base.get(ALLOC_FIELD), cur.get(ALLOC_FIELD)
    if b is None or c is None:
        row(ALLOC_FIELD, str(b), str(c), "-", "FAIL (missing)")
        failures.append(
            f"{ALLOC_FIELD}: missing from {'baseline' if b is None else 'current'} "
            "(gated fields must be present in both files)"
        )
    else:
        ok = c <= b + 1e-9
        row(ALLOC_FIELD, f"{b:.3f}", f"{c:.3f}", f"{c - b:+.3f}", "ok" if ok else "FAIL")
        if not ok:
            failures.append(
                f"{ALLOC_FIELD}: increased from {b:.3f} to {c:.3f} "
                "(any increase fails — the execute step must stay allocation-free)"
            )

    # Informational fields: everything numeric the two files share.
    for field in sorted(set(cur) & set(base)):
        if field in THROUGHPUT_FIELDS or field == ALLOC_FIELD:
            continue
        b, c = base[field], cur[field]
        if isinstance(b, (int, float)) and isinstance(c, (int, float)) and not isinstance(b, bool):
            delta = f"{(c - b) / b * 100.0:+.1f}%" if b else "-"
            row(field, f"{b}", f"{c}", delta, "info")

    header = ("field", "baseline", "current", "delta", "verdict")
    md = ["| " + " | ".join(header) + " |", "|" + "---|" * len(header)]
    md += ["| " + " | ".join(r) + " |" for r in rows]
    verdict = "PASS" if not failures else "FAIL:\n  " + "\n  ".join(failures)
    report = "### Perf gate\n\n" + "\n".join(md) + f"\n\n**{verdict}**\n"

    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as f:
            f.write(report)

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
